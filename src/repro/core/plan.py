"""Graph-level network planner — whole-network resource mapping.

The paper selects one IP per op against the available resources; a CNN
is a *graph* of ops competing for the same envelope.  This module is
the single selection engine behind every family:

* ``select_ip(family, spec, budget)`` — the generic per-site selector.
  A family is plannable once it registers a site adapter on its
  ``IPFamily`` (``core/library.py``); the old ``select_<family>_ip``
  functions in ``core/selector.py`` are thin shims over this.
* ``plan_network(specs, budget)`` — maps a list of ``SiteSpec`` sites
  onto ONE budget by *partitioning* it: each site gets a slice
  proportional to its estimated cost, with a greedy repair pass that
  floors every site at the minimal slice its cheapest member needs.
  This replaces the "every op sees the full budget" fiction the
  per-call-site selectors lived with.
* The **precision ladder**: a ``SiteSpec`` may declare narrower operand
  widths it tolerates (``ladder=(16, 8)``).  When a site cannot fit at
  its current width — under the full budget or under its partitioned
  slice — the planner descends the ladder *before* declaring
  infeasibility, re-running selection at the lowered width so packed
  int8 members (conv2d.ip3_packed, int8 matmul) and shrunken footprints
  enter the race.  The chosen width lands in
  ``PlannedSite.precision_bits`` and the execution layer
  (``repro.quant.ops``, ``models/blocks.py``) quantizes accordingly.
* Plans are memoized on ``(graph-key, budget)`` — repeated trace-time
  calls (e.g. re-tracing ``apply_cnn_block``) are O(1) dict hits with
  zero new footprint evaluations — and serialize to/from JSON for
  experiment artifacts.  The cache is LRU with observable statistics
  (``plan_cache_stats()``: hits, misses, evictions, occupancy) — the
  serving runtime surfaces these per tenant.
* **Fusion groups** (``fuse=True``): adjacent site runs a registered
  fused family absorbs (``IPFamily.fuses`` + ``fuse_sites``, e.g.
  conv->pool->act -> one ``cnn_fused`` site) are substituted when the
  fused member's combined footprint is feasible at the full budget and
  prices at or below the unfused chain, with per-group fallback to the
  three-site plan when the fused footprint breaks the partition
  (docs/adaptive_ips.md, "Fusion contract").
* ``replan(specs, new_budget)`` — the live re-planning fast path: when
  the serving arbiter shifts a tenant's budget slice, the graph is
  unchanged and only the envelope moved, so the expensive full-budget
  baseline (one ``_select_site`` per site) is skipped by reusing the
  graph's memoized *cost shares*; only slice assignment (and, on
  failure, the needs-floor repair) re-runs under the new budget.
  ``strict=True`` verifies the heuristic against a cold plan
  (``replan_strict_mismatch`` counts divergences caught).
* ``network_min_fraction(specs, budget)`` — the smallest fraction of a
  budget under which the graph still plans (ladder rungs included);
  the arbiter floors each tenant's share here.
* **Calibrated cost** (``calibration=``): every decision point that
  *ranks* — member selection, fusion-group substitution, the
  partitioner's cost shares — accepts a measurement-derived
  ``CalibrationTable`` (``core/calibrate_cost.py``) and prices
  footprints by predicted wall-clock instead of analytical
  ``est_cycles``.  Feasibility (fits, needs floors,
  ``network_min_fraction``) is deliberately untouched: calibration
  rescales cost, not resources.  Plans memoize on the table's
  ``key()`` (schema version + fits fingerprint), so a refitted table
  never serves stale cached plans (docs/adaptive_ips.md,
  "Calibration contract").

Everything here is pure trace-time Python: no jax arrays, no jit.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, Optional, Sequence, Tuple, Union

from repro.core.calibrate_cost import calibration_key, member_key
from repro.core.ip import IPFamily, KernelIP, SiteSpec
from repro.core.resources import Footprint, MeshSpec, ResourceBudget
from repro.obs.audit import PlanAudit, SiteAuditRecorder, unfit_reason
from repro.obs.trace import NOOP_SPAN, TRACER, log_event

_PLAN_CACHE_MAX = 1024
_SHARE_CACHE_MAX = 1024


@dataclasses.dataclass
class PlannerStats:
    """Trace-time observability: how much selection work actually ran."""

    selector_evals: int = 0     # candidate footprints priced by _select
    plan_hits: int = 0
    plan_misses: int = 0
    plan_evictions: int = 0     # LRU entries displaced at capacity
    replan_fast: int = 0        # replan() misses served via cached shares
    replan_cold: int = 0        # replan() misses that fell to a cold plan
    replan_strict_mismatch: int = 0  # strict=True caught a divergent
                                     # fast-path assignment
    fused_sites: int = 0        # fusion groups substituted into plans
    fused_fallbacks: int = 0    # groups unfused because the fused
                                # footprint broke the partition

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


class PartitionError(ValueError):
    """A graph's per-site minima jointly exceed the envelope — the
    partition (not any single site) is what failed.  Subclasses
    ValueError so callers keep catching the family-standard error; the
    fusion fallback keys on the type to know unfusing can help."""


STATS = PlannerStats()
# Insertion order is recency order: hits re-insert at the MRU end, and
# eviction pops the front — a plain dict is the LRU.
_PLAN_CACHE: Dict[tuple, "NetworkPlan"] = {}
# graph-key -> normalized full-budget cost shares (the replan fast path).
_SHARE_CACHE: Dict[tuple, Tuple[float, ...]] = {}
# original graph -> the fused/unfused site list the last cold plan
# settled on (the replan fast path re-uses it; a moved budget that
# breaks it falls back to a cold plan, which re-decides).
_FUSE_CACHE: Dict[tuple, Tuple[SiteSpec, ...]] = {}


def planner_stats() -> PlannerStats:
    return STATS


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _SHARE_CACHE.clear()
    _FUSE_CACHE.clear()


def _calkey_to_json(calkey):
    return list(calkey) if calkey is not None else None


def _calkey_from_json(raw):
    return tuple(raw) if raw is not None else None


def export_plan_cache() -> dict:
    """Serialize the planner's memo state — the plan-preserving-restart
    primitive (``runtime/recovery.py``).

    ``plans`` round-trips every plan-cache entry with its full key
    exactly as ``plan_network`` builds it: the site specs
    (``SiteSpec.to_dict``), the budget, the fuse flag, the mesh, and
    the calibration-table identity — plus the plan itself
    (``NetworkPlan.to_json``).  ``shares`` and ``fuses`` carry the
    ``replan`` fast path's memoized cost shares and fused site lists,
    so a restored process keeps the fast path too (a drifted grant
    after restart re-assigns from shares instead of falling cold).  A
    process that imports these entries serves its first request off the
    cache instead of paying a cold re-plan storm.
    """
    plans = []
    for (specs, budget, fuse, mesh, calkey), plan in _PLAN_CACHE.items():
        plans.append({
            "specs": [s.to_dict() for s in specs],
            "budget": dataclasses.asdict(budget),
            "fuse": bool(fuse),
            "mesh": dataclasses.asdict(mesh) if mesh is not None else None,
            "calibration_key": _calkey_to_json(calkey),
            "plan": json.loads(plan.to_json()),
        })
    shares = [{
        "specs": [s.to_dict() for s in specs],
        "calibration_key": _calkey_to_json(calkey),
        "shares": list(sh),
    } for (specs, calkey), sh in _SHARE_CACHE.items()]
    fuses = [{
        "specs": [s.to_dict() for s in specs],
        "calibration_key": _calkey_to_json(calkey),
        "effective": [s.to_dict() for s in eff],
    } for (specs, calkey), eff in _FUSE_CACHE.items()]
    return {"plans": plans, "shares": shares, "fuses": fuses}


def import_plan_cache(state: dict) -> int:
    """Seed the planner memo state from ``export_plan_cache`` output
    (the restore half of plan-preserving restart).  Counts neither hits
    nor misses — importing is not planning.  Returns the number of
    plan-cache entries inserted."""
    from repro.core.ip import SiteSpec

    def _specs(raw):
        return tuple(SiteSpec.from_dict(s) for s in raw)

    n = 0
    for e in state.get("plans", ()):
        budget = ResourceBudget(**e["budget"])
        mesh = MeshSpec(**e["mesh"]) if e.get("mesh") else None
        key = (_specs(e["specs"]), budget, bool(e["fuse"]), mesh,
               _calkey_from_json(e.get("calibration_key")))
        _cache_put(key, NetworkPlan.from_json(json.dumps(e["plan"])))
        n += 1
    for e in state.get("shares", ()):
        key = (_specs(e["specs"]),
               _calkey_from_json(e.get("calibration_key")))
        if key not in _SHARE_CACHE and len(_SHARE_CACHE) >= _SHARE_CACHE_MAX:
            _SHARE_CACHE.pop(next(iter(_SHARE_CACHE)))
        _SHARE_CACHE[key] = tuple(float(x) for x in e["shares"])
    for e in state.get("fuses", ()):
        key = (_specs(e["specs"]),
               _calkey_from_json(e.get("calibration_key")))
        if key not in _FUSE_CACHE and len(_FUSE_CACHE) >= _SHARE_CACHE_MAX:
            _FUSE_CACHE.pop(next(iter(_FUSE_CACHE)))
        _FUSE_CACHE[key] = _specs(e["effective"])
    return n


def plan_cache_stats() -> dict:
    """Cache observability for serving telemetry: occupancy + counters.

    Counters accumulate since process start (or the last manual reset of
    ``STATS``); callers wanting a window take two snapshots and diff.
    """
    lookups = STATS.plan_hits + STATS.plan_misses
    return {
        "size": len(_PLAN_CACHE),
        "capacity": _PLAN_CACHE_MAX,
        "hits": STATS.plan_hits,
        "misses": STATS.plan_misses,
        "evictions": STATS.plan_evictions,
        "replan_fast": STATS.replan_fast,
        "hit_rate": (STATS.plan_hits / lookups) if lookups else 0.0,
    }


def plan_cache_contains(specs, budget: Optional[ResourceBudget] = None, *,
                        fuse: bool = True, calibration=None,
                        mesh: Optional[MeshSpec] = None) -> bool:
    """True when the exact ``plan_network`` cache key is already warm.

    A pure membership probe — neither a hit nor a miss is counted and
    recency is untouched — so spare-plan pre-warming
    (``AdaptiveServer.prewarm_spares``) and the chaos gate can assert
    "this degraded-mesh key will serve hot" without perturbing the very
    statistics the zero-cold-replan claim is judged on."""
    budget = budget or ResourceBudget()
    key = (tuple(specs), budget, fuse, mesh, calibration_key(calibration))
    return key in _PLAN_CACHE


def _cache_get(key) -> Optional["NetworkPlan"]:
    plan = _PLAN_CACHE.pop(key, None)
    if plan is not None:
        _PLAN_CACHE[key] = plan        # refresh recency
    return plan


def _cache_put(key, plan: "NetworkPlan") -> None:
    if key not in _PLAN_CACHE and len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
        evicted = next(iter(_PLAN_CACHE))
        _PLAN_CACHE.pop(evicted)
        STATS.plan_evictions += 1
        log_event("plan_cache.evict", capacity=_PLAN_CACHE_MAX,
                  sites=len(evicted[0]), total=STATS.plan_evictions)
    _PLAN_CACHE[key] = plan


def _get_family(family: Union[str, IPFamily]) -> IPFamily:
    if isinstance(family, IPFamily):
        return family
    from repro.core.library import get_family
    return get_family(family)


# ---------------------------------------------------------------------------
# The selection engine (moved here from core/selector.py; the shims there
# keep the old five entry points alive).
# ---------------------------------------------------------------------------
def _rank(ip: KernelIP, fp: Footprint, budget: ResourceBudget,
          calibration=None, cal_suffix: str = ""):
    """Ranking key: (primary cost, tie-breaks). Lower is better.
    With a ``calibration`` table the primary cost is the measured-model
    prediction for this member's executed variant (``ip.name`` plus the
    lowered-rung suffix); the pressure multipliers and VMEM tie-break
    are unchanged — they steer *which* resources are spent, calibration
    corrects *how much* the spend costs."""
    parallel_bonus = 0
    if budget.prefer_parallel_streams:
        parallel_bonus = 0 if fp.outputs_per_pass >= 2 else 1
    mxu_pressure = 0.0
    if budget.mxu_passes_budget is not None and budget.mxu_passes_budget > 0:
        mxu_pressure = fp.mxu_passes / budget.mxu_passes_budget
    vpu_pressure = 0.0
    if budget.vpu_ops_budget is not None and budget.vpu_ops_budget > 0:
        vpu_pressure = fp.vpu_ops / budget.vpu_ops_budget
    # Normalize per produced output so dual-stream members aren't
    # penalized for doing two ops' work.
    cycles = (fp.calibrated_cycles(calibration, ip.name + cal_suffix)
              / max(fp.outputs_per_pass, 1))
    return (parallel_bonus, cycles * (1.0 + mxu_pressure + vpu_pressure),
            fp.vmem_bytes)


def _select(candidates: Sequence[KernelIP], budget: ResourceBudget,
            fp_args: tuple, fp_kwargs: dict, op_bits: int,
            calibration=None, cal_suffix: str = "", recorder=None,
            bits: int = 32):
    """Returns the winning (KernelIP, Footprint) pair.  With a
    ``recorder`` (``obs.audit.SiteAuditRecorder``) every candidate's
    verdict is recorded — rejections with the concrete budget axis that
    failed (``unfit_reason``), feasible losers with their ranking cost
    — the raw material of ``NetworkPlan.explain()``."""
    feasible = []
    for ip in candidates:
        STATS.selector_evals += 1
        fp = ip.footprint(*fp_args, **fp_kwargs)
        if op_bits > fp.max_operand_bits:
            if recorder is not None:
                recorder.candidate(
                    ip.name, bits, "rejected",
                    f"{op_bits}-bit operands exceed member ceiling "
                    f"int{fp.max_operand_bits}")
            continue
        if not fp.fits(budget):
            if recorder is not None:
                recorder.candidate(ip.name, bits, "rejected",
                                   unfit_reason(fp, budget))
            continue
        rank = _rank(ip, fp, budget, calibration, cal_suffix)
        if recorder is not None:
            recorder.candidate(ip.name, bits, "feasible", cost=rank[1])
        feasible.append((rank, ip.name, ip, fp))
    if not feasible:
        raise ValueError(
            "no feasible IP under budget "
            f"{budget} for shape args {fp_args} (operand bits {op_bits}); "
            f"candidates: {[c.name for c in candidates]}")
    feasible.sort(key=lambda t: t[:2])
    return feasible[0][2], feasible[0][3]


def _width_budget(budget: ResourceBudget, spec: SiteSpec,
                  bits: int) -> ResourceBudget:
    """The budget a site sees when planned at ``bits``.  A ladder entry
    is the site's explicit waiver of the deployment-wide precision
    floor: lowering to 8 bits caps ``precision_bits`` at 8 so 8-bit
    members (the LUT activation, the packed conv) become legal."""
    if bits >= spec.native_bits or budget.precision_bits <= bits:
        return budget
    return dataclasses.replace(budget, precision_bits=bits)


def _select_site(spec: SiteSpec, budget: ResourceBudget, calibration=None,
                 recorder=None):
    """Select for one site, descending its precision ladder on failure.

    Widths are tried native-first (precision is only sacrificed when the
    current width genuinely does not fit); each rung re-enters the full
    selection race at the lowered operand width, which both shrinks
    footprints (narrower itemsize) and unlocks width-capped members.
    Returns ``(KernelIP, Footprint, bits)``; raises the family-standard
    error only after the narrowest rung fails.  A ``recorder`` collects
    every rung's candidate verdicts for the plan decision audit.
    """
    fam = _get_family(spec.family)
    widths = spec.widths()
    if not fam.quantizable:
        widths = widths[:1]
    span = (TRACER.span("select", "plan", {"site": spec.name})
            if TRACER.enabled else NOOP_SPAN)
    err = None
    with span:
        for bits in widths:
            req = fam.plan_site(spec.at_precision(bits))
            suffix = f"@int{bits}" if bits < spec.native_bits else ""
            try:
                ip, fp = _select(req.candidates,
                                 _width_budget(budget, spec, bits),
                                 req.fp_args, dict(req.fp_kwargs),
                                 req.op_bits, calibration, suffix,
                                 recorder=recorder, bits=bits)
                if recorder is not None:
                    recorder.chose(ip.name, bits)
                return ip, fp, bits
            except ValueError as e:
                err = err or e      # surface the native-width failure
    raise err


def _site_cost(ip: KernelIP, fp: Footprint, bits: int, spec: SiteSpec,
               calibration=None) -> float:
    """One selected site's ranking cost: calibrated (or analytical)
    cycles per produced output."""
    key = member_key(ip.name, bits, spec.native_bits)
    return fp.calibrated_cycles(calibration, key) / max(fp.outputs_per_pass, 1)


def select_ip(family: Union[str, IPFamily], spec: SiteSpec,
              budget: Optional[ResourceBudget] = None,
              with_footprint: bool = False, calibration=None):
    """Generic resource-driven selection for one site of any family.

    The family's registered site adapter turns ``spec`` into candidates
    + footprint arguments; feasibility and ranking are identical for
    every family (docs/adaptive_ips.md#selection-semantics).  Sites with
    a precision ladder descend it on failure exactly as ``plan_network``
    does (use ``plan_single`` when the chosen width matters).
    """
    fam = _get_family(family)
    if spec.family != fam.name:
        raise ValueError(f"site {spec.name!r} is a {spec.family!r} site, "
                         f"not {fam.name!r}")
    budget = budget or ResourceBudget()
    ip, fp, _ = _select_site(spec, budget, calibration)
    return (ip, fp) if with_footprint else ip


# ---------------------------------------------------------------------------
# Network plans
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlannedSite:
    """One site's resolved decision: the member, its price, the fraction
    of the network budget the partitioner granted it, the operand
    width the precision ladder settled on (== the spec's native width
    when no lowering was needed), and the sharding the mesh pass chose
    (``shard_axis``/``shard_degree``; degree 1 means replicated).

    ``spec`` stays the GLOBAL site — what the caller's shapes validate
    against; the per-device shard is recoverable via
    ``NetworkPlan.device_plan()``.  A sharded site's ``footprint`` is
    its per-device footprint with the collective traffic folded in:
    ``comm_cycles`` carries the collective term and ``est_cycles``
    already includes it (docs/adaptive_ips.md, "Sharding contract")."""

    spec: SiteSpec
    ip: KernelIP
    footprint: Footprint
    fraction: float
    precision_bits: int = 32
    shard_axis: str = "none"
    shard_degree: int = 1

    @property
    def lowered(self) -> bool:
        return self.precision_bits < self.spec.native_bits

    @property
    def sharded(self) -> bool:
        return self.shard_degree > 1


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """A whole network mapped onto one ResourceBudget.

    Mapping-like: ``plan["layer0.conv"]`` returns the ``(KernelIP,
    Footprint)`` pair (the same shape the ad-hoc plan dicts used, so
    ``describe_plan`` renders either).
    """

    budget: ResourceBudget
    sites: Tuple[PlannedSite, ...]
    # The mesh this plan was priced against (None = single device, the
    # pre-mesh behavior).  A plan with mesh devices > 1 may carry
    # sharded sites; execution routes them through shard_map
    # (distributed/shard_exec.py).
    mesh: Optional[MeshSpec] = None
    # The decision audit the planner recorded while building this plan:
    # per-site candidate sets with rejection reasons, ladder-descent
    # notes, and plan-level events (fusion/shard/repair).  Excluded from
    # equality — two plans that map identically ARE the same plan even
    # if one was deserialized without its audit.  Rendered by
    # ``explain()`` (docs/adaptive_ips.md, "Observability contract").
    audit: Optional[PlanAudit] = dataclasses.field(
        default=None, compare=False, repr=False)

    def site(self, name: str) -> PlannedSite:
        for s in self.sites:
            if s.spec.name == name:
                return s
        raise KeyError(f"no site {name!r} in plan; "
                       f"have {[s.spec.name for s in self.sites]}")

    def __getitem__(self, name: str):
        s = self.site(name)
        return s.ip, s.footprint

    def __contains__(self, name: str) -> bool:
        return any(s.spec.name == name for s in self.sites)

    def __len__(self) -> int:
        return len(self.sites)

    def __iter__(self):
        return (s.spec.name for s in self.sites)

    def items(self):
        return [(s.spec.name, (s.ip, s.footprint)) for s in self.sites]

    @property
    def total_cycles(self) -> float:
        return sum(s.footprint.est_cycles / max(s.footprint.outputs_per_pass, 1)
                   for s in self.sites)

    def calibrated_cycles(self, calibration) -> float:
        """Total cost under a measurement-derived ``CalibrationTable``
        (``core/calibrate_cost.py``): each site's footprint priced by
        the fit of its executed variant (lowered rungs keyed
        ``@int<bits>``).  ``calibration=None`` degrades to
        ``total_cycles`` — the analytical model."""
        return sum(_site_cost(s.ip, s.footprint, s.precision_bits, s.spec,
                              calibration)
                   for s in self.sites)

    @property
    def total_launches(self) -> int:
        """Kernel launches one execution of this plan issues — the
        number fusion collapses (3 -> 1 per fused CNN block)."""
        return sum(s.footprint.launches for s in self.sites)

    def precision_of(self, name: str) -> int:
        """The operand width the ladder settled on for one site."""
        return self.site(name).precision_bits

    def lowered_sites(self) -> Tuple[PlannedSite, ...]:
        """Sites the precision ladder actually lowered below native."""
        return tuple(s for s in self.sites if s.lowered)

    def sharded_sites(self) -> Tuple[PlannedSite, ...]:
        """Sites the mesh pass actually split past one device."""
        return tuple(s for s in self.sites if s.sharded)

    def device_plan(self) -> "NetworkPlan":
        """The per-device view of a sharded plan: each sharded site's
        GLOBAL spec replaced by its per-device shard — the shapes
        execution actually sees inside ``shard_map``, and what the
        apply-path plan/site validation must match against.  A plan
        with no sharded sites returns itself."""
        if not any(s.sharded for s in self.sites):
            return self
        from repro.core.shard import shard_site_spec
        sites = tuple(
            dataclasses.replace(s, spec=shard_site_spec(
                s.spec, s.shard_axis, s.shard_degree))
            if s.sharded else s
            for s in self.sites)
        return dataclasses.replace(self, sites=sites)

    def describe(self) -> str:
        lines = []
        for s in self.sites:
            fp = s.footprint
            prec = (f"int{s.precision_bits}*" if s.lowered
                    else f"{s.precision_bits}b")
            shard = (f" {s.shard_axis}x{s.shard_degree}"
                     if s.sharded else "")
            lines.append(
                f"{s.spec.name:<40s} -> {s.ip.name:<28s} "
                f"p={prec:<6s} frac={s.fraction:5.3f} "
                f"vmem={fp.vmem_bytes/2**20:7.2f}MiB "
                f"mxu={fp.mxu_passes:<8d} vpu={fp.vpu_ops:.2e} "
                f"cyc={fp.est_cycles:.3e}{shard}")
        lines.append(f"{'TOTAL':<40s}    {'':<28s} "
                     f"cyc={self.total_cycles:.3e}")
        return "\n".join(lines)

    def explain(self) -> str:
        """Why this plan: per-site chosen member, every rejected
        candidate with the concrete budget axis that failed, ladder-
        descent notes, and the plan-level fusion/shard/repair events —
        the decision audit rendered for humans.  A plan that carries no
        audit (deserialized from pre-audit JSON) says so instead of
        pretending."""
        if self.audit is None:
            return "no audit recorded for this plan"
        return self.audit.render()

    # -- serialization ------------------------------------------------------
    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps({
            "budget": dataclasses.asdict(self.budget),
            "mesh": (dataclasses.asdict(self.mesh)
                     if self.mesh is not None else None),
            "audit": (self.audit.to_dict()
                      if self.audit is not None else None),
            "sites": [{
                "spec": s.spec.to_dict(),
                "ip": s.ip.name,
                "fraction": s.fraction,
                "precision_bits": s.precision_bits,
                "shard_axis": s.shard_axis,
                "shard_degree": s.shard_degree,
                "footprint": dataclasses.asdict(s.footprint),
            } for s in self.sites],
        }, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "NetworkPlan":
        from repro.core.library import get_ip
        d = json.loads(text)
        sites = []
        for r in d["sites"]:
            spec = SiteSpec.from_dict(r["spec"])
            sites.append(PlannedSite(
                spec=spec,
                ip=get_ip(r["ip"]),
                fraction=float(r["fraction"]),
                precision_bits=int(r.get("precision_bits",
                                         spec.native_bits)),
                shard_axis=r.get("shard_axis", "none"),
                shard_degree=int(r.get("shard_degree", 1)),
                footprint=Footprint(**r["footprint"]),
            ))
        mesh = d.get("mesh")
        audit = d.get("audit")
        return cls(budget=ResourceBudget(**d["budget"]),
                   sites=tuple(sites),
                   mesh=MeshSpec(**mesh) if mesh else None,
                   audit=PlanAudit.from_dict(audit) if audit else None)


# ---------------------------------------------------------------------------
# Budget partitioning
# ---------------------------------------------------------------------------
def _min_fraction(fp: Footprint, budget: ResourceBudget) -> float:
    """Smallest budget fraction under which ``fp`` still fits, given the
    integer truncation in ``ResourceBudget.scaled`` (the +1 keeps the
    truncated slice strictly above the requirement)."""
    ratios = [0.0]
    if fp.vmem_bytes > 0 and budget.vmem_bytes > 0:
        ratios.append((fp.vmem_bytes + 1) / budget.vmem_bytes)
    if fp.hbm_bytes > 0 and budget.hbm_bytes > 0:
        ratios.append((fp.hbm_bytes + 1) / budget.hbm_bytes)
    if budget.mxu_passes_budget is not None and fp.mxu_passes > 0:
        ratios.append((fp.mxu_passes + 1) / budget.mxu_passes_budget)
    if budget.vpu_ops_budget is not None and fp.vpu_ops > 0:
        ratios.append((fp.vpu_ops + 1) / budget.vpu_ops_budget)
    return max(ratios)


def _site_need(spec: SiteSpec, budget: ResourceBudget) -> float:
    """Minimal fraction at which *some* candidate of this site is
    feasible — at its native width or any ladder rung (capped at 1.0;
    full-budget feasibility is checked separately)."""
    fam = _get_family(spec.family)
    widths = spec.widths() if fam.quantizable else spec.widths()[:1]
    best = None
    for bits in widths:
        req = fam.plan_site(spec.at_precision(bits))
        wb = _width_budget(budget, spec, bits)
        for ip in req.candidates:
            STATS.selector_evals += 1
            fp = ip.footprint(*req.fp_args, **dict(req.fp_kwargs))
            if req.op_bits > fp.max_operand_bits:
                continue
            if not fp.fits(wb):        # full budget: non-scalable gates too
                continue
            f = min(_min_fraction(fp, wb), 1.0)
            best = f if best is None else min(best, f)
    return 1.0 if best is None else best


def plan_network(specs: Iterable[SiteSpec],
                 budget: Optional[ResourceBudget] = None, *,
                 fuse: bool = True, calibration=None,
                 mesh: Optional[MeshSpec] = None) -> "NetworkPlan":
    """Map a network of sites onto one partitioned budget (memoized).

    Partitioning: fractions proportional to each site's cheapest
    full-budget cost; if any site has no feasible member under its
    slice, a greedy repair pass floors every site at its minimal
    feasible fraction and redistributes only the surplus.  Raises the
    family-standard ``ValueError`` when a site is infeasible even under
    the full budget, or when the sites' minimal needs exceed the
    envelope.

    ``fuse=True`` (the default since the calibration benchmarks showed
    the calibrated fused-vs-unfused ranking matches measured wall-clock
    on every budget; pass ``fuse=False`` to opt out) turns on
    **fusion-aware planning**: adjacent runs a
    registered fused family absorbs (e.g. conv->pool->act, declared via
    ``IPFamily.fuses``) are substituted by the single fused site when
    the fused member is feasible at the full budget and its combined
    footprint prices at or below the unfused chain's; groups whose
    fused footprint then breaks the partition are unfused again one at
    a time (largest minimal need first) until the plan closes — the
    fused plan can only ever *gain* feasibility over the unfused one.

    ``mesh=`` (a ``MeshSpec`` with devices > 1) turns on **mesh-sharded
    planning**: per site the planner chooses between replicating on one
    device and splitting across all of them (batch- or channel-
    parallel, ``core/shard.py``), pricing each split's collective
    traffic — psum for channel-split convs, boundary/egress all-gathers
    — in cycles at the mesh's link bandwidth via
    ``Footprint.comm_cycles``.  Each device sees the FULL ``budget``
    (that is what an N-device grant means); a site infeasible on one
    device but feasible split is rescued by the shard.  Sharded sites
    keep their GLOBAL spec (``NetworkPlan.device_plan()`` recovers the
    per-device view); execution lowers them through ``shard_map``
    (``distributed/shard_exec.py``).

    ``calibration=`` re-ranks every cost comparison (member selection,
    the fused-vs-unfused decision, the partition shares) by the table's
    measured-model predictions; feasibility and floors are unchanged.
    The plan cache keys on the table's identity
    (``CalibrationTable.key()``), so plans under different — or
    refitted — tables never collide.
    """
    budget = budget or ResourceBudget()
    key = (tuple(specs), budget, fuse, mesh, calibration_key(calibration))
    cached = _cache_get(key)
    if cached is not None:
        STATS.plan_hits += 1
        return cached
    STATS.plan_misses += 1
    with (TRACER.span("plan_network", "plan",
                      {"sites": len(key[0]), "fuse": fuse,
                       "mesh_devices": mesh.devices if mesh else 1})
          if TRACER.enabled else NOOP_SPAN):
        plan = _plan_uncached(key[0], budget, fuse=fuse,
                              calibration=calibration, mesh=mesh)
    _cache_put(key, plan)
    return plan


def replan(specs: Iterable[SiteSpec],
           budget: Optional[ResourceBudget] = None, *,
           fuse: bool = True, strict: bool = False,
           calibration=None,
           mesh: Optional[MeshSpec] = None) -> "NetworkPlan":
    """Re-plan a known graph under a moved budget — the serving fast path.

    Exact ``(graph, budget)`` repeats are cache hits like
    ``plan_network``.  On a miss for a graph planned before, the
    full-budget baseline (one ladder-descending selection per site —
    the bulk of a cold plan's footprint evaluations) is skipped by
    reusing the graph's memoized cost shares (and, with ``fuse=True``,
    its memoized fused/unfused site list); only slice assignment runs
    under the new budget, with the needs-floor repair on failure.  A
    graph never planned before falls through to ``plan_network``; so do
    fast-path failures, to surface the canonical errors (or rescue a
    plan the stale shares missed).  ``planner_stats()`` counts the
    split: ``replan_fast`` misses served off cached shares vs
    ``replan_cold`` misses that fell to a cold plan.

    **The fast path is a heuristic**: stale shares can settle on a
    different (still feasible, possibly less lowered) assignment than a
    cold plan of the same ``(graph, budget)`` would.  ``strict=True`` is
    the escape hatch: the fast-path result is verified against the cold
    plan and silently replaced by it on divergence
    (``replan_strict_mismatch`` counts the catches) — tests and audits
    run strict; the serving loop accepts the heuristic.

    With ``calibration=`` the fast path reuses only shares memoized
    under the *same* table identity — a refreshed (refitted) table
    finds no shares and falls cold, re-deriving the assignment from the
    new predictions instead of serving a stale-calibration split.

    With ``mesh=`` (devices > 1) the share heuristic does not apply —
    the sharding decisions depend on mesh geometry, not just the moved
    envelope — so the call goes through the full (memoized)
    ``plan_network`` path; exact repeats are still O(1) cache hits.
    """
    budget = budget or ResourceBudget()
    if mesh is not None and mesh.devices > 1:
        return plan_network(specs, budget, fuse=fuse, mesh=mesh,
                            calibration=calibration)
    specs = tuple(specs)
    calkey = calibration_key(calibration)
    # same key shape as plan_network (mesh slot None here) so no-mesh
    # replans and plans share cache entries
    key = (specs, budget, fuse, None, calkey)
    cached = None if strict else _cache_get(key)
    if cached is not None:
        STATS.plan_hits += 1
        return cached
    eff = _FUSE_CACHE.get((specs, calkey)) if fuse else specs
    shares = (_SHARE_CACHE.get((eff, calkey))
              if eff is not None else None)
    if shares is None:
        STATS.replan_cold += 1
        if not strict:
            return plan_network(specs, budget, fuse=fuse,
                                calibration=calibration)
        # strict must not trust plan_network's cache: a prior NON-strict
        # replan may have stored its heuristic plan under this very key.
        STATS.plan_misses += 1
        plan = _plan_uncached(specs, budget, fuse=fuse,
                              calibration=calibration)
        _cache_put(key, plan)
        return plan
    STATS.plan_misses += 1
    fell_cold = False
    try:
        with (TRACER.span("replan", "plan", {"sites": len(eff)})
              if TRACER.enabled else NOOP_SPAN):
            plan = _assign_with_repair(
                eff, budget, shares, calibration=calibration,
                events=["replan fast path: assignment from memoized "
                        "cost shares (no full-budget baseline)"])
        STATS.replan_fast += 1
    except ValueError:
        STATS.replan_cold += 1
        fell_cold = True
        plan = _plan_uncached(specs, budget, fuse=fuse,
                              calibration=calibration)
    if strict and not fell_cold:   # a fallen-cold plan IS the cold plan
        cold = _plan_uncached(specs, budget, fuse=fuse,
                              calibration=calibration)
        if _assignment(plan) != _assignment(cold):
            STATS.replan_strict_mismatch += 1
            plan = cold
    _cache_put(key, plan)
    return plan


def _assignment(plan: "NetworkPlan") -> tuple:
    """What 'same decision' means for strict replan verification: the
    member and operand width chosen per site (fractions may wiggle)."""
    return tuple((s.spec.name, s.ip.name, s.precision_bits)
                 for s in plan.sites)


def network_min_fraction(specs: Iterable[SiteSpec],
                         budget: Optional[ResourceBudget] = None) -> float:
    """Smallest fraction of ``budget`` under which ``specs`` still plans.

    The budget partitioner grants every site at least the minimal slice
    its cheapest member (at its cheapest legal ladder width) needs, so a
    scaled-down envelope is feasible exactly while those per-site minima
    still sum within it.  The serving arbiter floors each tenant's share
    here — with a ladder, the floor already reflects the narrowest rung
    the tenant tolerates (degrade-before-fail).
    """
    budget = budget or ResourceBudget()
    return min(1.0, sum(_site_need(s, budget) for s in specs))


def plan_single(spec: SiteSpec,
                budget: Optional[ResourceBudget] = None,
                calibration=None) -> "PlannedSite":
    """One-site plan (the kernels' ``budget=`` path): full budget, same
    engine, same memoization.  Returns the ``PlannedSite`` — callers
    needing only the member read ``.ip``; the quantized wrappers also
    read ``.precision_bits`` to decide whether to lower execution."""
    return plan_network((spec,), budget,
                        calibration=calibration).site(spec.name)


def _try_assign(specs: Tuple[SiteSpec, ...], budget: ResourceBudget,
                fractions: Sequence[float], calibration=None):
    """One assignment pass; returns (planned, failed, audits) where
    ``audits`` carries one ``SiteAudit`` per *planned* site (None for
    failed ones — a failed pass's audits die with it; the repair pass
    records the audits the final plan ships)."""
    planned, failed, audits = [], [], []
    for spec, frac in zip(specs, fractions):
        rec = SiteAuditRecorder(spec.name, spec.family, spec.native_bits)
        try:
            ip, fp, bits = _select_site(spec, budget.scaled(frac),
                                        calibration, recorder=rec)
            planned.append(PlannedSite(spec=spec, ip=ip, footprint=fp,
                                       fraction=frac,
                                       precision_bits=bits))
            audits.append(rec.finish(ip.name, bits, frac))
        except ValueError:
            planned.append(None)
            audits.append(None)
            failed.append(spec.name)
    return planned, failed, audits


def _assign_with_repair(specs: Tuple[SiteSpec, ...], budget: ResourceBudget,
                        shares: Sequence[float],
                        calibration=None, events=None) -> NetworkPlan:
    """Slice assignment under cost ``shares``, with the greedy repair:
    if any site has no feasible member under its proportional slice,
    every site is floored at the minimal slice its cheapest member (at
    its cheapest legal width) needs and only the surplus follows the
    shares.  ``events`` (a list) accumulates plan-level audit events;
    the built plan carries the full ``PlanAudit``."""
    events = events if events is not None else []
    planned, failed, audits = _try_assign(specs, budget, shares, calibration)
    if failed:
        needs = [_site_need(s, budget) for s in specs]
        total_need = sum(needs)
        if total_need > 1.0 + 1e-9:
            raise PartitionError(
                f"no feasible network plan under budget {budget}: sites "
                f"{[s.name for s in specs]} jointly need {total_need:.3f}x "
                f"the envelope "
                f"(per-site minima {['%.3f' % n for n in needs]})")
        surplus = 1.0 - total_need
        fractions = [need + surplus * share
                     for need, share in zip(needs, shares)]
        events.append(
            f"partition repair: sites {failed} infeasible at proportional "
            f"shares; floored every site at its minimal need "
            f"(total {total_need:.3f}) and redistributed the surplus")
        planned, failed, audits = _try_assign(specs, budget, fractions,
                                              calibration)
        if failed:  # pragma: no cover — needs floor guarantees feasibility
            raise ValueError(
                f"budget partition repair failed for sites {failed} under "
                f"{budget}")
    audit = PlanAudit(sites=tuple(audits), events=tuple(events))
    return NetworkPlan(budget=budget, sites=tuple(planned), audit=audit)


# ---------------------------------------------------------------------------
# Fusion groups — substitute a registered fused family's single site for
# the adjacent run of op sites it absorbs (docs/adaptive_ips.md,
# "Fusion contract").
# ---------------------------------------------------------------------------
def _fusion_groups(specs: Tuple[SiteSpec, ...]):
    """Adjacent runs some fused family absorbs: [(start, length,
    fused_spec)], non-overlapping, left-to-right greedy."""
    from repro.core.library import FAMILIES
    fusers = [f for f in FAMILIES.values() if f.fuses and f.fuse_sites]
    groups = []
    i = 0
    while i < len(specs):
        step = 1
        for fam in fusers:
            ln = len(fam.fuses)
            run = specs[i:i + ln]
            if (len(run) == ln
                    and tuple(s.family for s in run) == fam.fuses):
                fspec = fam.fuse_sites(tuple(run))
                if fspec is not None:
                    groups.append((i, ln, fspec))
                    step = ln
                    break
        i += step
    return groups


def _substitute(specs: Tuple[SiteSpec, ...], groups) -> Tuple[SiteSpec, ...]:
    out = list(specs)
    for start, length, fspec in sorted(groups, reverse=True):
        out[start:start + length] = [fspec]
    return tuple(out)


def _fused_specs(specs: Tuple[SiteSpec, ...], select, calibration=None,
                 events=None):
    """The fusion decision at full budget: substitute a group's fused
    site when the fused member is feasible AND its combined footprint
    prices at or below the unfused chain's cheapest members (or the
    chain is outright infeasible — fusion can rescue it).  Returns
    ``(effective_specs, chosen_groups)``.

    This comparison is where the analytical model was most wrong
    (ROADMAP: fused modeled cheaper everywhere, measured slower on half
    the budgets), so with ``calibration`` both sides re-rank by the
    measured-model cost of their selected members — groups unfuse when
    the measurements say the one-launch member is the slower path."""
    chosen = []
    for start, length, fspec in _fusion_groups(specs):
        chain = [s.name for s in specs[start:start + length]]
        try:
            fip, ffp, fbits = select(fspec)
        except ValueError:
            if events is not None:
                events.append(
                    f"fusion rejected: {fspec.name} has no feasible "
                    f"member at the full budget; chain {chain} "
                    f"stays unfused")
            continue
        fcost = _site_cost(fip, ffp, fbits, fspec, calibration)
        try:
            ucost = 0.0
            for s in specs[start:start + length]:
                uip, ufp, ubits = select(s)
                ucost += _site_cost(uip, ufp, ubits, s, calibration)
        except ValueError:
            ucost = None
        if ucost is None or fcost <= ucost:
            chosen.append((start, length, fspec))
            if events is not None:
                why = ("unfused chain infeasible" if ucost is None else
                       f"cost {fcost:.3e} <= unfused chain {ucost:.3e}")
                events.append(
                    f"fusion: {fspec.name} replaces {chain} ({why})")
        elif events is not None:
            events.append(
                f"fusion rejected: {fspec.name} costs {fcost:.3e} > "
                f"unfused chain {ucost:.3e}; chain {chain} stays unfused")
    return _substitute(specs, chosen), chosen


def _plan_uncached(specs: Tuple[SiteSpec, ...], budget: ResourceBudget,
                   fuse: bool = False, calibration=None,
                   mesh: Optional[MeshSpec] = None) -> NetworkPlan:
    if not specs:
        return NetworkPlan(budget=budget, sites=(), mesh=mesh)
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate site names in network: {dupes}")
    calkey = calibration_key(calibration)

    # One full-budget selection per distinct site for this whole call:
    # the fusion decision and the baseline price the same specs, and the
    # fallback retries re-price surviving sites.
    memo: Dict[SiteSpec, tuple] = {}

    def select_full(spec: SiteSpec):
        if spec not in memo:
            memo[spec] = _select_site(spec, budget, calibration)
        return memo[spec]

    events: list = []
    eff, chosen = (_fused_specs(specs, select_full, calibration,
                                events=events) if fuse
                   else (specs, []))
    while True:
        try:
            if mesh is not None and mesh.devices > 1:
                # The sharding pass runs INSIDE the fallback loop: when
                # a fused group later unfuses, the new chain re-decides
                # its splits (the fused site's batch-only rule no
                # longer binds).
                from repro.core.shard import plan_shard_decisions
                shardings = plan_shard_decisions(
                    eff, budget, mesh, select_full, calibration,
                    events=events)
                plan = _plan_effective(
                    tuple(sh.spec for sh in shardings), budget,
                    select_full, calibration=calibration, calkey=calkey,
                    events=events)
                plan = _apply_shardings(plan, eff, shardings, budget,
                                        mesh)
            else:
                plan = _plan_effective(eff, budget, select_full,
                                       calibration=calibration,
                                       calkey=calkey, events=events)
                if mesh is not None:
                    plan = dataclasses.replace(plan, mesh=mesh)
            break
        except ValueError as e:
            # Only a broken partition is fusion's fault (every chosen
            # fused member was verified feasible at the full budget); a
            # per-site "no feasible IP" cannot be fixed by unfusing.
            if not chosen or not isinstance(e, PartitionError):
                raise
            # The fused VMEM need broke the partition: unfuse the group
            # with the largest minimal slice and retry — the fully
            # unfused list is the guaranteed-no-worse floor.
            STATS.fused_fallbacks += 1
            needs = [(_site_need(f, budget), idx)
                     for idx, (_, _, f) in enumerate(chosen)]
            _, drop = max(needs)
            events.append(
                f"fusion fallback: unfused {chosen[drop][2].name} after "
                f"partition failure (largest minimal slice "
                f"{needs[drop][0]:.3f})")
            chosen = chosen[:drop] + chosen[drop + 1:]
            eff = _substitute(specs, chosen)
    if fuse:
        STATS.fused_sites += len(chosen)
        _FUSE_CACHE[(specs, calkey)] = eff
        if len(_FUSE_CACHE) > _SHARE_CACHE_MAX:
            _FUSE_CACHE.pop(next(iter(_FUSE_CACHE)))
    return plan


def _apply_shardings(plan: NetworkPlan, eff: Tuple[SiteSpec, ...],
                     shardings, budget: ResourceBudget,
                     mesh: MeshSpec) -> NetworkPlan:
    """Map a plan built on per-device shard specs back to the GLOBAL
    specs, folding each site's collective cycles into its footprint:
    ``comm_cycles`` carries the collective term and ``est_cycles``
    grows by it, so ``total_cycles``/``calibrated_cycles`` price the
    traffic and the calibration layer can regress on the comm axis."""
    sites = []
    for ps, sh, gspec in zip(plan.sites, shardings, eff):
        if sh.degree > 1 or sh.comm_cycles:
            fp = dataclasses.replace(
                ps.footprint,
                est_cycles=ps.footprint.est_cycles + sh.comm_cycles,
                comm_cycles=sh.comm_cycles)
            sites.append(dataclasses.replace(
                ps, spec=gspec, footprint=fp, shard_axis=sh.axis,
                shard_degree=sh.degree))
        else:
            sites.append(ps)
    # dataclasses.replace keeps the audit the assignment pass recorded.
    return dataclasses.replace(plan, sites=tuple(sites), mesh=mesh)


def _plan_effective(specs: Tuple[SiteSpec, ...], budget: ResourceBudget,
                    select=None, calibration=None, calkey=None,
                    events=None) -> NetworkPlan:
    # 1) Full-budget baseline: cost shares (raises "no feasible IP" for a
    #    site that cannot run even with everything — after descending its
    #    precision ladder, when it has one).
    if select is None:
        select = lambda s: _select_site(s, budget, calibration)  # noqa: E731
    if calkey is None:
        calkey = calibration_key(calibration)
    base = [select(s) for s in specs]
    costs = [_site_cost(ip, fp, bits, s, calibration)
             for s, (ip, fp, bits) in zip(specs, base)]
    total_cost = sum(costs) or 1.0
    shares = tuple(c / total_cost for c in costs)
    # Memoize the shares for replan(): they shift a little across
    # budgets (the baseline winners may differ), but stay a sound
    # starting assignment — the repair pass recomputes exact needs
    # under whatever budget replan() is handed.  Keyed on the
    # calibration fingerprint too: a refitted table changes the shares.
    if ((specs, calkey) not in _SHARE_CACHE
            and len(_SHARE_CACHE) >= _SHARE_CACHE_MAX):
        _SHARE_CACHE.pop(next(iter(_SHARE_CACHE)))
    _SHARE_CACHE[(specs, calkey)] = shares
    return _assign_with_repair(specs, budget, shares, calibration,
                               events=events)


# ---------------------------------------------------------------------------
# Fixed-IP baselines (benchmarks/table3): price a fixed family->member
# assignment over the same sites the planner maps.
# ---------------------------------------------------------------------------
def fixed_network_cost(specs: Iterable[SiteSpec],
                       members: Dict[str, str],
                       budget: Optional[ResourceBudget] = None,
                       calibration=None):
    """Total est-cycles of a fixed assignment, or None if any site is
    infeasible.  Each site is generously priced against the FULL budget
    (no partitioning) — the planner has to win despite that handicap.

    ``members`` maps family name -> member name (short or qualified).
    ``calibration`` prices with measured scale factors when given, so the
    baseline and the planner are compared under the same cost model.
    """
    budget = budget or ResourceBudget()
    total = 0.0
    for spec in specs:
        fam = _get_family(spec.family)
        req = fam.plan_site(spec)
        want = members[spec.family]
        cands = {c.name: c for c in req.candidates}
        qual = want if "." in want else f"{spec.family}.{want}"
        ip = cands.get(qual)
        if ip is None:      # member not even a candidate for this site
            return None
        fp = ip.footprint(*req.fp_args, **dict(req.fp_kwargs))
        if req.op_bits > fp.max_operand_bits or not fp.fits(budget):
            return None
        total += _site_cost(ip, fp, spec.native_bits, spec, calibration)
    return total
