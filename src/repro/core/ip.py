"""KernelIP — one entry of the adaptive IP library.

The paper ships four VHDL IPs, each a (behaviour, resource-contract)
pair.  Here an IP is a callable plus a ``footprint(shape)`` function that
prices it against the TPU resource vector, plus the static capability
bits from paper Table I (operand-width ceiling, outputs per pass,
whether it needs the MXU).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from repro.core.resources import Footprint, ResourceBudget


@dataclasses.dataclass(frozen=True)
class KernelIP:
    name: str                 # e.g. "conv2d.ip3_packed"
    family: str               # "conv2d" | "matmul" | "attention"
    impl: Callable[..., Any]  # the jit-able implementation
    footprint_fn: Callable[..., Footprint]
    description: str = ""
    # Static capability bits (paper Table I columns):
    uses_mxu: bool = True
    max_operand_bits: int = 32
    outputs_per_pass: int = 1
    supports_dtypes: Tuple[str, ...] = ("int8", "bfloat16", "float32")
    tags: Tuple[str, ...] = ()

    def footprint(self, *shape_args, **shape_kwargs) -> Footprint:
        fp = self.footprint_fn(*shape_args, **shape_kwargs)
        # The static ceiling is authoritative; a footprint_fn may tighten
        # it per-shape but never widen it.
        return dataclasses.replace(
            fp, max_operand_bits=min(fp.max_operand_bits, self.max_operand_bits),
            outputs_per_pass=self.outputs_per_pass)

    def feasible(self, budget: ResourceBudget, *shape_args, **shape_kwargs) -> bool:
        return self.footprint(*shape_args, **shape_kwargs).fits(budget)

    def __call__(self, *args, **kwargs):
        return self.impl(*args, **kwargs)


@dataclasses.dataclass
class IPFamily:
    """All IPs implementing one op contract (same ref.py oracle)."""

    name: str
    members: Dict[str, KernelIP] = dataclasses.field(default_factory=dict)
    reference: Optional[Callable[..., Any]] = None

    def register(self, ip: KernelIP) -> KernelIP:
        if ip.name in self.members:
            raise ValueError(f"duplicate IP {ip.name!r} in family {self.name!r}")
        self.members[ip.name] = ip
        return ip

    def __iter__(self):
        return iter(self.members.values())

    def __getitem__(self, name: str) -> KernelIP:
        if name in self.members:
            return self.members[name]
        # allow short names: "ip3_packed" for "conv2d.ip3_packed"
        qual = f"{self.name}.{name}"
        if qual in self.members:
            return self.members[qual]
        raise KeyError(f"no IP {name!r} in family {self.name!r}; "
                       f"have {sorted(self.members)}")

    def names(self) -> Sequence[str]:
        return sorted(self.members)
