"""KernelIP — one entry of the adaptive IP library.

The paper ships four VHDL IPs, each a (behaviour, resource-contract)
pair.  Here an IP is a callable plus a ``footprint(shape)`` function that
prices it against the TPU resource vector, plus the static capability
bits from paper Table I (operand-width ceiling, outputs per pass,
whether it needs the MXU).

``SiteSpec`` / ``SiteRequest`` are the planner-facing half of the
contract: a family registers a *site adapter* (``IPFamily.site_adapter``,
populated in ``core/library.py``) that translates a declarative op site
— family, shapes, dtype, knobs — into the candidate set and footprint
arguments the generic selection engine (``core/plan.py``) prices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from repro.core.resources import Footprint, ResourceBudget


def _freeze(value):
    """Normalize knob/shape values to hashable, JSON-stable forms."""
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v) for v in value)
    return value


# Widths a precision ladder may assign — exactly the widths the quant
# execution layer (repro.quant.ops) can run — and the fixed-point dtype
# a lowered site is priced (and, for int8, executed) at.  A native-width
# rung is never "lowered", so 32 is deliberately NOT a legal ladder
# entry: it would plan a lowering the runtime rejects.
LADDER_WIDTHS = (16, 8)
WIDTH_DTYPES = {8: "int8", 16: "int16"}


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One op site of a network graph, declaratively.

    Hashable (it is the planner's cache-key unit) and JSON-serializable.
    ``shapes`` holds the operand shapes the family adapter expects (e.g.
    ``(x_shape, w_shape)`` for conv2d); ``knobs`` are the op-level
    switches (``dual``, ``mode``, ``kind``, ``window``...) as a sorted
    tuple of pairs so equal specs hash equally.

    ``ladder`` is the site's *precision ladder*: the operand widths (in
    bits, e.g. ``(16, 8)``) the planner may quantize this site down to
    when it cannot fit at its native width (docs/adaptive_ips.md,
    "Precision contract").  Empty means the native width is the only
    legal one — the pre-ladder behavior.
    """

    name: str
    family: str
    shapes: Tuple[Tuple[int, ...], ...]
    dtype: str = "float32"
    knobs: Tuple[Tuple[str, Any], ...] = ()
    ladder: Tuple[int, ...] = ()

    @classmethod
    def make(cls, name: str, family: str, shapes, dtype="float32",
             ladder=(), **knobs) -> "SiteSpec":
        import jax.numpy as jnp
        norm_shapes = tuple(tuple(int(d) for d in s) for s in shapes)
        norm_knobs = tuple(sorted((k, _freeze(v)) for k, v in knobs.items()))
        norm_ladder = tuple(sorted({int(b) for b in ladder}, reverse=True))
        for b in norm_ladder:
            if b not in LADDER_WIDTHS:
                raise ValueError(f"unsupported ladder width {b}; "
                                 f"have {sorted(LADDER_WIDTHS)}")
        return cls(name=name, family=family, shapes=norm_shapes,
                   dtype=jnp.dtype(dtype).name, knobs=norm_knobs,
                   ladder=norm_ladder)

    def knob(self, key: str, default=None):
        for k, v in self.knobs:
            if k == key:
                return v
        return default

    @property
    def native_bits(self) -> int:
        """Physical width of the caller's operands at this site."""
        import jax.numpy as jnp
        return jnp.dtype(self.dtype).itemsize * 8

    def widths(self) -> Tuple[int, ...]:
        """Widths the planner may try, native first then the ladder's
        strictly-narrower rungs in descending order."""
        native = self.native_bits
        return (native,) + tuple(b for b in self.ladder if b < native)

    def at_precision(self, bits: int) -> "SiteSpec":
        """This site lowered to ``bits``-wide fixed-point operands (the
        spec the family adapter prices); native width returns self."""
        if bits >= self.native_bits:
            return self
        return dataclasses.replace(self, dtype=WIDTH_DTYPES[bits])

    def to_dict(self) -> dict:
        return {"name": self.name, "family": self.family,
                "shapes": [list(s) for s in self.shapes],
                "dtype": self.dtype,
                "knobs": {k: list(v) if isinstance(v, tuple) else v
                          for k, v in self.knobs},
                "ladder": list(self.ladder)}

    @classmethod
    def from_dict(cls, d: dict) -> "SiteSpec":
        return cls.make(d["name"], d["family"], d["shapes"], d["dtype"],
                        ladder=d.get("ladder", ()), **d.get("knobs", {}))


@dataclasses.dataclass(frozen=True)
class SiteRequest:
    """What a family's site adapter hands the selection engine: the
    candidate members to price, the arguments their footprint functions
    take for this site, and the physical operand width of the caller's
    data (0 when the member re-encodes on ingest — see
    docs/adaptive_ips.md)."""

    candidates: Tuple["KernelIP", ...]
    fp_args: Tuple
    fp_kwargs: Tuple[Tuple[str, Any], ...] = ()
    op_bits: int = 32


@dataclasses.dataclass(frozen=True)
class KernelIP:
    name: str                 # e.g. "conv2d.ip3_packed"
    family: str               # "conv2d" | "matmul" | "attention"
    impl: Callable[..., Any]  # the jit-able implementation
    footprint_fn: Callable[..., Footprint]
    description: str = ""
    # Static capability bits (paper Table I columns):
    uses_mxu: bool = True
    max_operand_bits: int = 32
    outputs_per_pass: int = 1
    supports_dtypes: Tuple[str, ...] = ("int8", "bfloat16", "float32")
    tags: Tuple[str, ...] = ()

    def footprint(self, *shape_args, **shape_kwargs) -> Footprint:
        fp = self.footprint_fn(*shape_args, **shape_kwargs)
        # The static ceiling is authoritative; a footprint_fn may tighten
        # it per-shape but never widen it.
        return dataclasses.replace(
            fp, max_operand_bits=min(fp.max_operand_bits, self.max_operand_bits),
            outputs_per_pass=self.outputs_per_pass)

    def feasible(self, budget: ResourceBudget, *shape_args, **shape_kwargs) -> bool:
        return self.footprint(*shape_args, **shape_kwargs).fits(budget)

    def __call__(self, *args, **kwargs):
        return self.impl(*args, **kwargs)


@dataclasses.dataclass
class IPFamily:
    """All IPs implementing one op contract (same ref.py oracle).

    ``site_adapter`` makes the family plannable: it maps a ``SiteSpec``
    to a ``SiteRequest`` so the generic engine in ``core/plan.py`` can
    select for this family without family-specific code.

    ``quantizable`` gates the precision ladder: only families with a
    real fixed-point execution path (``repro.quant.ops``) may have their
    sites lowered below native width.  Attention and the SSM scan have
    no integer kernels, so pricing them at int8 would promise a plan the
    runtime cannot execute.

    **Fusion contract** (docs/adaptive_ips.md, "Fusion contract"): a
    family whose members absorb a *chain* of op families into one launch
    declares the chain in ``fuses`` (program order, e.g. ``("conv2d",
    "pool2d", "activation")``) and registers a ``fuse_sites`` adapter
    mapping that many adjacent SiteSpecs to the single fused SiteSpec —
    or ``None`` when the run is not fusable (wrong knobs, shapes that
    don't chain).  ``plan_network(..., fuse=True)`` scans every planned
    graph for such runs generically; it never hard-codes a family.
    """

    name: str
    members: Dict[str, KernelIP] = dataclasses.field(default_factory=dict)
    reference: Optional[Callable[..., Any]] = None
    site_adapter: Optional[Callable[[SiteSpec], SiteRequest]] = None
    quantizable: bool = True
    fuses: Tuple[str, ...] = ()
    fuse_sites: Optional[Callable[[Tuple[SiteSpec, ...]],
                                  Optional[SiteSpec]]] = None

    def plan_site(self, spec: SiteSpec) -> SiteRequest:
        if spec.family != self.name:
            raise ValueError(f"site {spec.name!r} is a {spec.family!r} site, "
                             f"not {self.name!r}")
        if self.site_adapter is None:
            raise NotImplementedError(
                f"family {self.name!r} has no site adapter registered; "
                "it cannot be planned (see docs/adaptive_ips.md)")
        return self.site_adapter(spec)

    def register(self, ip: KernelIP) -> KernelIP:
        if ip.name in self.members:
            raise ValueError(f"duplicate IP {ip.name!r} in family {self.name!r}")
        self.members[ip.name] = ip
        return ip

    def __iter__(self):
        return iter(self.members.values())

    def __getitem__(self, name: str) -> KernelIP:
        if name in self.members:
            return self.members[name]
        # allow short names: "ip3_packed" for "conv2d.ip3_packed"
        qual = f"{self.name}.{name}"
        if qual in self.members:
            return self.members[qual]
        raise KeyError(f"no IP {name!r} in family {self.name!r}; "
                       f"have {sorted(self.members)}")

    def names(self) -> Sequence[str]:
        return sorted(self.members)
