"""Measurement-calibrated cost model — close the loop the paper leaves open.

``Footprint.est_cycles`` is an *analytical* cost: compute cycles plus DMA
cycles from first principles (``core/resources.py::cost_cycles``).  It
ranks members well within a family, but across execution paths it can be
provably wrong: ``BENCH_table_fusion.json`` shows fused plans modeled
strictly cheaper on all 6 budgets while measured wall-clock is *slower*
on 3 of them.  A planner optimizing a wrong objective caps the whole
system, so this module adds the hardware-measured feedback loop:

1. **Record** ``(family, member, footprint, measured us)`` samples — the
   timing substrate is the same median-of-N harness ``core/autotune.py``
   and ``benchmarks/run.py::_timeit`` use (``timeit_us``), and
   ``measure_planned_site`` / ``collect_plan_samples`` execute exactly
   the members a ``NetworkPlan`` chose, lowered rungs included.
2. **Fit** a per-(family, member) affine model over the footprint's
   analytical axes::

       predicted_us = a * compute_cycles + b * hbm_bytes + c

   by least squares with coefficients clamped nonnegative (so calibrated
   cost is nondecreasing in compute and traffic, and never negative).  A
   member with fewer than ``min_samples`` (default 3) observations falls
   back to one *global* fit over every sample — a coarse scale is sounder
   than an unconstrained plane through two points.
3. **Predict**: ``CalibrationTable.calibrated_cycles(footprint, member)``
   converts the predicted wall-clock back into cycle units
   (``us * CLOCK_HZ``) so calibrated and analytical costs stay mutually
   comparable; a member no fit covers (empty table) keeps its
   ``est_cycles`` — the identity calibration.

The planner consumes the table through ``calibration=`` parameters
(``core/plan.py``): member ranking, fusion-group substitution, and the
partitioner's cost shares all re-rank by calibrated cost, while
*feasibility* (``Footprint.fits``, needs floors, ``network_min_fraction``)
is untouched — calibration rescales cost, it does not change what fits.
Plan memoization keys on ``CalibrationTable.key()`` (schema version +
fits fingerprint), so a refitted table invalidates stale plans.

**Lowered rungs are distinct members.**  A site the precision ladder
lowered executes a different code path (``repro.quant.ops`` wrappers), so
its samples and fits key as ``"<ip.name>@int<bits>"`` (``member_key``) —
per-(family, member) granularity where "member" is the executed variant.

Persistence: ``save``/``load`` round-trip the table as versioned JSON
bit-exactly (floats serialize via repr); ``load`` rejects unknown schema
versions.  See docs/adaptive_ips.md, "Calibration contract".
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.resources import CLOCK_HZ, Footprint
from repro.obs.trace import NOOP_SPAN, TRACER

# v2 adds the collective axis (``comm_cycles`` on samples,
# ``us_per_comm_cycle`` on fits) for mesh-sharded sites; v1 tables load
# with the new axis defaulted to zero — their predictions are unchanged.
CALIBRATION_SCHEMA_VERSION = 2
_ACCEPTED_SCHEMA_VERSIONS = (1, 2)

# Defaults for the measurement harness: one discarded warmup call, then
# the median of this many timed calls (matches benchmarks/run.py).
MEASURE_REPEAT = 3


def timeit_us(fn, *args, warmup: int = 1, repeat: int = MEASURE_REPEAT,
              **kwargs) -> float:
    """us/call: ``warmup`` discarded calls, then the median of ``repeat``
    timed calls — the shared wall-clock substrate of the benchmarks, the
    autotuner's measure mode, and calibration sampling."""
    import jax
    import numpy as np
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(max(1, repeat)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def member_key(ip_name: str, bits: Optional[int] = None,
               native_bits: int = 32) -> str:
    """The calibration key for one executed variant of a member: the
    qualified IP name, suffixed with ``@int<bits>`` when the precision
    ladder lowered the site below its native width (the quantized
    execution path is a different code path, hence a different fit)."""
    if bits is not None and bits < native_bits:
        return f"{ip_name}@int{bits}"
    return ip_name


@dataclasses.dataclass(frozen=True)
class CalibrationSample:
    """One measured observation: what a member's launch actually cost at
    one footprint point.  ``compute_cycles``/``hbm_bytes`` are the
    analytical axes the affine fit regresses over."""

    family: str
    member: str
    compute_cycles: float
    hbm_bytes: float
    measured_us: float
    comm_cycles: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationSample":
        return cls(family=d["family"], member=d["member"],
                   compute_cycles=float(d["compute_cycles"]),
                   hbm_bytes=float(d["hbm_bytes"]),
                   measured_us=float(d["measured_us"]),
                   comm_cycles=float(d.get("comm_cycles", 0.0)))


@dataclasses.dataclass(frozen=True)
class AffineFit:
    """``predicted_us = us_per_compute_cycle * compute
    + us_per_hbm_byte * hbm_bytes + us_per_comm_cycle * comm
    + overhead_us`` with every coefficient >= 0 (enforced at fit time),
    so predictions are nonnegative and nondecreasing in every axis.
    ``us_per_comm_cycle`` calibrates collective traffic exactly like
    compute and HBM; tables fit before the mesh work (schema v1) carry
    an implicit zero."""

    us_per_compute_cycle: float
    us_per_hbm_byte: float
    overhead_us: float
    n_samples: int
    us_per_comm_cycle: float = 0.0

    def predict_us(self, compute_cycles: float, hbm_bytes: float,
                   comm_cycles: float = 0.0) -> float:
        return (self.us_per_compute_cycle * compute_cycles
                + self.us_per_hbm_byte * hbm_bytes
                + self.us_per_comm_cycle * comm_cycles + self.overhead_us)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AffineFit":
        return cls(us_per_compute_cycle=float(d["us_per_compute_cycle"]),
                   us_per_hbm_byte=float(d["us_per_hbm_byte"]),
                   overhead_us=float(d["overhead_us"]),
                   n_samples=int(d["n_samples"]),
                   us_per_comm_cycle=float(d.get("us_per_comm_cycle", 0.0)))


def _affine_fit(
        rows: Sequence[Tuple[float, float, float, float]]) -> AffineFit:
    """Least-squares affine fit of (compute, hbm, comm) -> us with
    coefficients clamped nonnegative: solve, drop the most negative
    coefficient's column, re-solve — a small active-set NNLS sufficient
    for 4 columns.  (An all-zero comm column — every single-device
    sample — is rank-deficient; lstsq's min-norm solution leaves its
    coefficient at zero, the correct no-information answer.)
    """
    import numpy as np
    X = np.array([[c, h, m, 1.0] for c, h, m, _ in rows], dtype=np.float64)
    y = np.array([us for _, _, _, us in rows], dtype=np.float64)
    active = [0, 1, 2, 3]
    coef = np.zeros(4)
    while active:
        sol, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        if all(s >= 0.0 for s in sol):
            for col, s in zip(active, sol):
                coef[col] = float(s)
            break
        worst = min(range(len(sol)), key=lambda i: sol[i])
        active.pop(worst)
    return AffineFit(us_per_compute_cycle=float(coef[0]),
                     us_per_hbm_byte=float(coef[1]),
                     us_per_comm_cycle=float(coef[2]),
                     overhead_us=float(coef[3]), n_samples=len(rows))


class CalibrationTable:
    """Samples + fits + persistence; see module docstring.

    Mutable by design — a serving process records samples as it runs and
    ``fit()`` refreshes the model.  Identity for cache keying is
    ``key()``: predictions only change when the *fits* change, so
    recording samples alone leaves memoized plans valid, while ``fit()``
    moves the fingerprint and invalidates them.
    """

    def __init__(self, samples: Iterable[CalibrationSample] = (),
                 fits: Optional[Dict[str, AffineFit]] = None,
                 global_fit: Optional[AffineFit] = None,
                 min_samples: int = 3):
        self.samples: List[CalibrationSample] = list(samples)
        self.fits: Dict[str, AffineFit] = dict(fits or {})
        self.global_fit: Optional[AffineFit] = global_fit
        self.min_samples = int(min_samples)
        self._fingerprint: Optional[str] = None

    # -- sampling -----------------------------------------------------------
    def record(self, member: str, footprint: Footprint, measured_us: float,
               *, family: Optional[str] = None,
               bits: Optional[int] = None, native_bits: int = 32) -> None:
        """Append one observation.  ``member`` is the qualified IP name
        (``"conv2d.ip1_vpu"``); pass ``bits``/``native_bits`` to key a
        ladder-lowered execution under its ``@int<bits>`` variant.  The
        fit axes come from the footprint's analytical split
        (``Footprint.compute_cycles`` / ``hbm_bytes``)."""
        key = member_key(member, bits, native_bits)
        self.samples.append(CalibrationSample(
            family=family or member.partition(".")[0],
            member=key,
            compute_cycles=float(footprint.compute_cycles),
            hbm_bytes=float(footprint.hbm_bytes),
            measured_us=float(measured_us),
            comm_cycles=float(footprint.comm_cycles)))

    def sample_count(self, member: Optional[str] = None) -> int:
        if member is None:
            return len(self.samples)
        return sum(1 for s in self.samples if s.member == member)

    # -- fitting ------------------------------------------------------------
    def fit(self, min_samples: Optional[int] = None) -> "CalibrationTable":
        """(Re)fit per-member models; members with fewer than
        ``min_samples`` observations get no dedicated fit and fall back
        to the global fit over every sample.  Returns self (chainable).
        """
        if min_samples is not None:
            self.min_samples = int(min_samples)
        with (TRACER.span("calibration.fit", "calibrate",
                          {"samples": len(self.samples)})
              if TRACER.enabled else NOOP_SPAN):
            by_member: Dict[str, List[Tuple[float, float, float,
                                            float]]] = {}
            for s in self.samples:
                by_member.setdefault(s.member, []).append(
                    (s.compute_cycles, s.hbm_bytes, s.comm_cycles,
                     s.measured_us))
            self.fits = {m: _affine_fit(rows)
                         for m, rows in by_member.items()
                         if len(rows) >= self.min_samples}
            all_rows = [(s.compute_cycles, s.hbm_bytes, s.comm_cycles,
                         s.measured_us)
                        for s in self.samples]
            self.global_fit = _affine_fit(all_rows) if all_rows else None
            self._fingerprint = None
        return self

    # -- prediction ---------------------------------------------------------
    def fit_for(self, member: str) -> Optional[AffineFit]:
        """The fit predictions for ``member`` use: its dedicated fit, or
        the global fallback, or None when the table has never been fit
        on any sample (identity calibration)."""
        return self.fits.get(member, self.global_fit)

    def predict_us(self, member: str, compute_cycles: float,
                   hbm_bytes: float,
                   comm_cycles: float = 0.0) -> Optional[float]:
        f = self.fit_for(member)
        if f is None:
            return None
        return max(f.predict_us(compute_cycles, hbm_bytes, comm_cycles),
                   0.0)

    def calibrated_cycles(self, footprint: Footprint, member: str) -> float:
        """The footprint's cost under this table, in cycle units: the
        predicted wall-clock scaled by the core clock, so calibrated
        costs rank against each other exactly as the measurements do.
        Falls back to ``est_cycles`` when no fit covers the member.

        A member with no fitted comm coefficient (all its samples were
        single-device) still pays its ``comm_cycles`` at the analytical
        rate — collective traffic never becomes free just because it
        was not measured yet."""
        us = self.predict_us(member, footprint.compute_cycles,
                             footprint.hbm_bytes, footprint.comm_cycles)
        if us is None:
            return footprint.est_cycles
        cycles = us * 1e-6 * CLOCK_HZ
        f = self.fit_for(member)
        if footprint.comm_cycles and f is not None \
                and f.us_per_comm_cycle == 0.0:
            cycles += footprint.comm_cycles
        return cycles

    # -- identity -----------------------------------------------------------
    def fingerprint(self) -> str:
        """Digest of the *fits* (not the raw samples): two tables that
        predict identically share a fingerprint, and refitting moves it
        — the planner's cache-keying rule."""
        if self._fingerprint is None:
            payload = json.dumps(
                {"fits": {m: f.to_dict() for m, f in sorted(self.fits.items())},
                 "global_fit": (self.global_fit.to_dict()
                                if self.global_fit else None)},
                sort_keys=True)
            self._fingerprint = hashlib.sha256(
                payload.encode()).hexdigest()[:16]
        return self._fingerprint

    def key(self) -> tuple:
        """Hashable identity for plan memoization: (schema version,
        fits fingerprint)."""
        return (CALIBRATION_SCHEMA_VERSION, self.fingerprint())

    # -- persistence --------------------------------------------------------
    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps({
            "version": CALIBRATION_SCHEMA_VERSION,
            "min_samples": self.min_samples,
            "samples": [s.to_dict() for s in self.samples],
            "fits": {m: f.to_dict() for m, f in sorted(self.fits.items())},
            "global_fit": (self.global_fit.to_dict()
                           if self.global_fit else None),
        }, indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationTable":
        d = json.loads(text)
        version = d.get("version")
        if version not in _ACCEPTED_SCHEMA_VERSIONS:
            raise ValueError(
                f"calibration table schema version {version!r} is not "
                f"supported (accepted {_ACCEPTED_SCHEMA_VERSIONS}); "
                "re-collect samples and refit")
        return cls(
            samples=[CalibrationSample.from_dict(s) for s in d["samples"]],
            fits={m: AffineFit.from_dict(f) for m, f in d["fits"].items()},
            global_fit=(AffineFit.from_dict(d["global_fit"])
                        if d.get("global_fit") else None),
            min_samples=int(d.get("min_samples", 3)))

    def save(self, path) -> None:
        Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path) -> "CalibrationTable":
        return cls.from_json(Path(path).read_text())

    def __eq__(self, other) -> bool:
        return (isinstance(other, CalibrationTable)
                and self.samples == other.samples
                and self.fits == other.fits
                and self.global_fit == other.global_fit
                and self.min_samples == other.min_samples)


def calibration_key(calibration: Optional[CalibrationTable]) -> Optional[tuple]:
    """The cache-key component for an optional table (None stays None —
    the uncalibrated planner's keys are unchanged)."""
    return None if calibration is None else calibration.key()


# ---------------------------------------------------------------------------
# Measurement: execute exactly what a plan chose, one site at a time.
# ---------------------------------------------------------------------------
def _synthetic(shape, dtype, rng):
    """An input tensor of the site's declared shape/dtype (seeded)."""
    import jax.numpy as jnp
    import numpy as np
    dt = np.dtype(dtype)
    if dt.kind in "iu":
        info = np.iinfo(dt)
        lo, hi = max(info.min, -128), min(info.max, 127)
        return jnp.asarray(rng.integers(lo, hi + 1, size=shape, dtype=dt))
    return jnp.asarray(rng.normal(size=shape).astype(dt))


def _site_runner(site, *, interpret: bool = True, seed: int = 0):
    """A zero-arg callable executing one planned site's member on
    synthetic operands — the same dispatch ``models/blocks.py`` performs,
    lowered rungs (quantized wrappers) included."""
    import numpy as np
    rng = np.random.default_rng(seed)
    spec, ip, bits = site.spec, site.ip, site.precision_bits
    lowered = site.lowered
    fam = spec.family
    if fam == "conv2d":
        x = _synthetic(spec.shapes[0], spec.dtype, rng)
        w = _synthetic(spec.shapes[1], spec.dtype, rng)
        if lowered:
            from repro.quant.ops import quantized_conv2d
            return lambda: quantized_conv2d(x, w, bits=bits, ip=ip.name,
                                            interpret=interpret)
        if ip.outputs_per_pass >= 2:
            from repro.kernels.conv2d.ops import conv2d_dual
            x2 = _synthetic(spec.shapes[0], spec.dtype, rng)
            return lambda: conv2d_dual(x, x2, w, ip=ip.name,
                                       interpret=interpret)
        from repro.kernels.conv2d.ops import conv2d
        return lambda: conv2d(x, w, ip=ip.name, interpret=interpret)
    if fam == "pool2d":
        x = _synthetic(spec.shapes[0], spec.dtype, rng)
        kw = dict(window=spec.knob("window", (2, 2)),
                  stride=spec.knob("stride"),
                  mode=spec.knob("mode", "max"))
        if lowered:
            from repro.quant.ops import quantized_pool2d
            return lambda: quantized_pool2d(x, bits=bits, ip=ip.name,
                                            interpret=interpret, **kw)
        from repro.kernels.pool2d.ops import pool2d
        return lambda: pool2d(x, ip=ip.name, interpret=interpret, **kw)
    if fam == "activation":
        x = _synthetic(spec.shapes[0], spec.dtype, rng)
        kind = spec.knob("kind", "relu")
        if lowered:
            from repro.quant.ops import quantized_activation
            return lambda: quantized_activation(x, kind=kind, bits=bits,
                                                ip=ip.name,
                                                interpret=interpret)
        from repro.kernels.activation.ops import activation
        return lambda: activation(x, kind=kind, ip=ip.name,
                                  interpret=interpret)
    if fam == "cnn_fused":
        x = _synthetic(spec.shapes[0], spec.dtype, rng)
        w = _synthetic(spec.shapes[1], spec.dtype, rng)
        kw = dict(pool_window=spec.knob("window", (2, 2)),
                  pool_stride=spec.knob("stride"),
                  pool_mode=spec.knob("mode", "max"),
                  activation=spec.knob("kind", "relu"))
        if lowered:
            from repro.quant.ops import quantized_fused_cnn_block
            return lambda: quantized_fused_cnn_block(
                x, w, bits=bits, ip=ip.name, interpret=interpret, **kw)
        from repro.kernels.fused.ops import fused_cnn_block
        return lambda: fused_cnn_block(x, w, ip=ip.name,
                                       interpret=interpret, **kw)
    if fam == "matmul":
        a = _synthetic(spec.shapes[0], spec.dtype, rng)
        b = _synthetic(spec.shapes[1], spec.dtype, rng)
        if lowered:
            from repro.quant.ops import quantized_matmul
            return lambda: quantized_matmul(a, b, bits=bits, ip=ip.name,
                                            interpret=interpret)
        from repro.kernels.matmul.ops import matmul
        return lambda: matmul(a, b, ip=ip.name, interpret=interpret)
    raise ValueError(f"no calibration runner for family {fam!r} "
                     f"(site {spec.name!r})")


def measure_planned_site(site, *, interpret: bool = True,
                         warmup: int = 1, repeat: int = MEASURE_REPEAT,
                         seed: int = 0) -> float:
    """Measured us/call for one ``PlannedSite``: the planned member runs
    standalone on synthetic operands of the site's declared shapes, via
    the exact dispatch the execution layer uses (quantized wrappers for
    lowered rungs)."""
    with (TRACER.span("calibration.measure", "calibrate",
                      {"site": site.spec.name, "member": site.ip.name,
                       "bits": site.precision_bits})
          if TRACER.enabled else NOOP_SPAN):
        return timeit_us(
            _site_runner(site, interpret=interpret, seed=seed),
            warmup=warmup, repeat=repeat)


def collect_plan_samples(plans, table: Optional[CalibrationTable] = None, *,
                         interpret: bool = True, warmup: int = 1,
                         repeat: int = MEASURE_REPEAT,
                         seed: int = 0) -> CalibrationTable:
    """Measure every distinct (member, width, site) a set of plans chose
    and record the samples — the warmup pass of a calibration loop.

    Distinctness is per executed variant: the same member at two layer
    shapes yields two samples (different footprint points — exactly what
    the affine fit needs), while re-planning the same site under another
    budget does not re-measure.  Returns the (new or given) table;
    call ``fit()`` on it when sampling is done.

    Sharded sites are skipped: their footprint is the per-device shard
    plus collective cycles, which a standalone single-process runner
    cannot reproduce — the comm axis is calibrated from whole-plan mesh
    measurements (``benchmarks/run.py::table_mesh``) instead.
    """
    table = table if table is not None else CalibrationTable()
    seen = set()
    for plan in plans:
        if plan is None:
            continue
        for site in plan.sites:
            if getattr(site, "shard_degree", 1) > 1:
                continue
            dkey = (site.ip.name, site.precision_bits, site.spec)
            if dkey in seen:
                continue
            seen.add(dkey)
            us = measure_planned_site(site, interpret=interpret,
                                      warmup=warmup, repeat=repeat,
                                      seed=seed)
            table.record(site.ip.name, site.footprint, us,
                         family=site.spec.family,
                         bits=site.precision_bits,
                         native_bits=site.spec.native_bits)
    return table
