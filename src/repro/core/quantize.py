"""Compatibility shim — the fixed-point subsystem moved to ``repro.quant``.

Quantization grew from a matmul-only helper into a first-class, planned
dimension (per-site precision ladders, calibration, per-family quantized
execution); the real module tree is ``src/repro/quant/``.  This file
keeps the historical import path alive for existing callers.
"""
from repro.quant.quantize import (MIN_SCALE, QuantizedTensor, dequantize,
                                  fake_quant, int8_matmul,
                                  quantization_error, quantize_acts,
                                  quantize_weights)

__all__ = [
    "MIN_SCALE", "QuantizedTensor", "dequantize", "fake_quant",
    "int8_matmul", "quantization_error", "quantize_acts",
    "quantize_weights",
]
