"""Fixed-point (int8) path for the LM hot ops — the paper's arithmetic
discipline applied beyond convolution.

Symmetric per-channel weight quantization + per-tensor activation
quantization feeding the int8 matmul IP (`mm_mxu` int8 / the
`mm_dual_shared` Conv3-analogue).  W8A8 with int32 accumulation and
f32 rescale — the standard TPU int8 serving recipe, and the direct
generalization of the paper's "8-bit fixed-point data" experiments.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QuantizedTensor(NamedTuple):
    q: jnp.ndarray          # int8 payload
    scale: jnp.ndarray      # f32; () per-tensor or (channels,) per-channel


def quantize_weights(w: jnp.ndarray, *, axis: int = -1) -> QuantizedTensor:
    """Symmetric per-output-channel int8 quantization."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=tuple(
        i for i in range(w.ndim) if i != (axis % w.ndim)), keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q, scale.astype(jnp.float32))


def quantize_acts(x: jnp.ndarray) -> QuantizedTensor:
    """Symmetric per-tensor int8 activation quantization."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32))) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q, scale.astype(jnp.float32))


def int8_matmul(x: jnp.ndarray, wq: QuantizedTensor, *,
                use_kernel: bool = False) -> jnp.ndarray:
    """y = x @ dequant(wq): int8 x int8 -> int32 accumulate, f32 rescale.

    ``use_kernel=True`` routes through the Pallas mm_mxu int8 kernel
    (interpret mode on CPU); otherwise the jnp twin lowers the same
    int32-accumulation contraction.
    """
    xq = quantize_acts(x)
    if use_kernel:
        from repro.kernels.matmul.mxu import mm_mxu
        acc = mm_mxu(xq.q.reshape(-1, xq.q.shape[-1]), wq.q)
        acc = acc.reshape(x.shape[:-1] + (wq.q.shape[-1],))
    else:
        acc = jnp.einsum("...k,kn->...n", xq.q.astype(jnp.int32),
                         wq.q.astype(jnp.int32))
    out_scale = xq.scale * wq.scale.reshape(
        (1,) * (acc.ndim - 1) + (-1,))
    return acc.astype(jnp.float32) * out_scale


def quantization_error(w: jnp.ndarray, axis: int = -1) -> float:
    """Relative Frobenius error of the weight quantization (diagnostic)."""
    wq = quantize_weights(w, axis=axis)
    deq = wq.q.astype(jnp.float32) * wq.scale
    return float(jnp.linalg.norm(deq - w) / (jnp.linalg.norm(w) + 1e-12))
