"""Block-shape autotuning for the kernel IPs.

The paper sizes each IP to its resource budget by hand; this module
automates the remaining free parameters (BlockSpec tile shapes) the way
the dry-run does everything else: score candidate tilings against the
footprint cost model (VMEM fit -> feasibility; est_cycles -> rank),
optionally refined by wall-clock measurement in interpret mode.

    best = autotune_matmul(m, k, n, budget=ResourceBudget())
    y = mm_mxu(a, b, **best.params)
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.resources import (LANE, MXU_DIM, Footprint, ResourceBudget,
                                  SUBLANE)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    params: Dict[str, int]
    footprint: Footprint
    est_cycles: float
    measured_us: Optional[float] = None


def _aligned(lo: int, hi: int, align: int) -> List[int]:
    out = []
    v = align
    while v <= hi:
        if v >= lo:
            out.append(v)
        v *= 2
    return out or [align]


def sweep(footprint_fn: Callable[..., Footprint], grid: Dict[str, Sequence[int]],
          budget: ResourceBudget, *fp_args, top: int = 3,
          measure: Optional[Callable[..., float]] = None,
          **fp_kwargs) -> List[TuneResult]:
    """Generic sweep: rank feasible tilings by est_cycles (then VMEM)."""
    names = list(grid)
    results: List[TuneResult] = []
    for combo in itertools.product(*(grid[n] for n in names)):
        params = dict(zip(names, combo))
        fp = footprint_fn(*fp_args, **fp_kwargs, **params)
        if not fp.fits(budget):
            continue
        results.append(TuneResult(params, fp, fp.est_cycles))
    results.sort(key=lambda r: (r.est_cycles, r.footprint.vmem_bytes))
    results = results[:top]
    if measure is not None:
        measured = []
        for r in results:
            us = measure(**r.params)
            measured.append(dataclasses.replace(r, measured_us=us))
        measured.sort(key=lambda r: r.measured_us)
        return measured
    return results


def autotune_matmul(m: int, k: int, n: int, *, itemsize: int = 2,
                    budget: Optional[ResourceBudget] = None,
                    measure: bool = False, table=None) -> TuneResult:
    """Tile sweep for mm_mxu; MXU-aligned candidates only.

    ``measure=True`` refines the top analytical candidates by wall
    clock (the shared ``calibrate_cost.timeit_us`` median harness);
    passing a ``CalibrationTable`` as ``table`` additionally records
    each (footprint, measured µs) pair as a calibration sample for the
    ``matmul.mm_mxu`` member — the tuner doubles as a sample collector.
    """
    from repro.kernels.matmul.mxu import footprint_mxu, mm_mxu
    budget = budget or ResourceBudget()
    grid = {"bm": _aligned(MXU_DIM, min(m, 1024), MXU_DIM),
            "bn": _aligned(MXU_DIM, min(n, 1024), MXU_DIM),
            "bk": _aligned(MXU_DIM, min(k, 2048), MXU_DIM)}
    meas = None
    if measure or table is not None:
        import numpy as np
        import jax.numpy as jnp
        from repro.core.calibrate_cost import timeit_us
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))

        def run(**params):
            us = timeit_us(mm_mxu, a, b, **params)
            if table is not None:
                table.record("matmul.mm_mxu",
                             footprint_mxu(m, k, n, itemsize=itemsize,
                                           **params),
                             us, family="matmul")
            return us

        meas = run
    res = sweep(footprint_mxu, grid, budget, m, k, n, itemsize=itemsize,
                measure=meas)
    if not res:
        raise ValueError(f"no feasible matmul tiling for ({m},{k},{n}) "
                         f"under {budget}")
    return res[0]


def autotune_flash(b: int, hq: int, hkv: int, sq: int, skv: int, d: int, *,
                   itemsize: int = 2,
                   budget: Optional[ResourceBudget] = None) -> TuneResult:
    """Chunk sweep for flash attention (bq, bk)."""
    from repro.kernels.attention.flash import footprint
    budget = budget or ResourceBudget()
    grid = {"bq": _aligned(SUBLANE * 16, min(sq, 2048), 128),
            "bk": _aligned(LANE, min(skv, 4096), 128)}
    res = sweep(footprint, grid, budget, b, hq, hkv, sq, skv, d,
                itemsize=itemsize)
    if not res:
        raise ValueError("no feasible flash tiling")
    return res[0]


def autotune_conv(n: int, h: int, w: int, cin: int, kh: int, kw: int,
                  cout: int, *, ip: str = "ip2_mxu", itemsize: int = 1,
                  budget: Optional[ResourceBudget] = None) -> TuneResult:
    """Cout-block sweep for the conv IPs."""
    import importlib
    mod = importlib.import_module(
        f"repro.kernels.conv2d.{ip if ip.startswith('ip') else 'ip2_mxu'}")
    budget = budget or ResourceBudget()
    grid = {"block_cout": _aligned(LANE, max(cout, LANE), LANE)}
    res = sweep(mod.footprint, grid, budget, n, h, w, cin, kh, kw, cout,
                itemsize=itemsize)
    if not res:
        raise ValueError("no feasible conv tiling")
    return res[0]


def autotune_fused(n: int, h: int, w: int, cin: int, kh: int, kw: int,
                   cout: int, ph: int, pw: int, sh: int, sw: int, *,
                   ip: str = "fused_mxu", itemsize: int = 1,
                   mode: str = "max", kind: str = "relu",
                   budget: Optional[ResourceBudget] = None) -> TuneResult:
    """Cout-block sweep for the fused conv->pool->act members."""
    from repro.kernels.fused import cnn_block as fused_mod
    fp_fn = (fused_mod.footprint_mxu if ip.endswith("mxu")
             else fused_mod.footprint_vpu)
    budget = budget or ResourceBudget()
    grid = {"block_cout": _aligned(LANE, max(cout, LANE), LANE)}
    res = sweep(fp_fn, grid, budget, n, h, w, cin, kh, kw, cout,
                ph, pw, sh, sw, itemsize=itemsize, mode=mode, kind=kind)
    if not res:
        raise ValueError("no feasible fused-block tiling")
    return res[0]


# ---------------------------------------------------------------------------
# Plan bridge — tile choices for the sites of a NetworkPlan.
# ---------------------------------------------------------------------------
# Families/members with sweepable tiling parameters; everything else in a
# plan runs its member's built-in defaults.
_TUNABLE = {("conv2d", "ip2_mxu"), ("matmul", "mm_mxu"),
            ("cnn_fused", "fused_vpu"), ("cnn_fused", "fused_mxu")}


def plan_tile_overrides(plan) -> Dict[str, Dict[str, int]]:
    """Autotuned tiling parameters for the tunable sites of a
    ``NetworkPlan`` — the bridge from the tuner to executed plans.

    Returns ``{site_name: tiling_kwargs}`` suitable for the
    ``tile_overrides=`` parameter of ``apply_cnn_block`` /
    ``apply_cnn_frontend`` (the serving runtime threads it through when
    its ``autotune=`` flag is on).  Each site is tuned against the slice
    of the plan's budget the partitioner granted it, so a tuned tiling
    can never outgrow the envelope the plan certified.  Lowered sites
    keep their quantized wrappers' defaults, and a site whose sweep
    finds no feasible tiling is skipped — its member's default already
    passed the selector's feasibility check.
    """
    import numpy as np
    out: Dict[str, Dict[str, int]] = {}
    for site in plan.sites:
        short = site.ip.name.split(".")[-1]
        if site.lowered or (site.spec.family, short) not in _TUNABLE:
            continue
        sub = plan.budget.scaled(site.fraction)
        itemsize = np.dtype(site.spec.dtype).itemsize
        try:
            if site.spec.family == "conv2d":
                x_shape, w_shape = site.spec.shapes
                n, h, w = x_shape[0], x_shape[1], x_shape[2]
                kh, kw, cin, cout = w_shape
                res = autotune_conv(n, h, w, cin, kh, kw, cout, ip=short,
                                    itemsize=itemsize, budget=sub)
            elif site.spec.family == "cnn_fused":
                from repro.kernels.pool2d.ref import check_pool_geometry
                x_shape, w_shape = site.spec.shapes
                n, h, w = x_shape[0], x_shape[1], x_shape[2]
                kh, kw, cin, cout = w_shape
                (ph, pw), (sh, sw) = check_pool_geometry(
                    (n, h - kh + 1, w - kw + 1, cout),
                    site.spec.knob("window", (2, 2)),
                    site.spec.knob("stride"))
                res = autotune_fused(
                    n, h, w, cin, kh, kw, cout, ph, pw, sh, sw, ip=short,
                    itemsize=itemsize, mode=site.spec.knob("mode", "max"),
                    kind=site.spec.knob("kind", "relu"), budget=sub)
            else:
                a_shape, b_shape = site.spec.shapes
                res = autotune_matmul(a_shape[-2], a_shape[-1], b_shape[-1],
                                      itemsize=itemsize, budget=sub)
        except ValueError:
            continue
        out[site.spec.name] = dict(res.params)
    return out
