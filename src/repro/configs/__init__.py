"""Architecture config registry: ``get_config("olmo-1b")`` etc."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES,
                                shape_applicable)

_MODULES = {
    "olmo-1b": "olmo_1b",
    "starcoder2-15b": "starcoder2_15b",
    "chatglm3-6b": "chatglm3_6b",
    "llama3.2-1b": "llama3_2_1b",
    "dbrx-132b": "dbrx_132b",
    "grok-1-314b": "grok_1_314b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {n: get_config(n, smoke=smoke) for n in ARCH_NAMES}


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "get_config", "all_configs", "ARCH_NAMES"]
