"""dbrx-132b [moe] — 40L d=6144 48H (GQA kv=8) ff=10752 V=100352,
MoE 16 experts top-4 (fine-grained). [hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    norm="layernorm", activation="swiglu", rope_style="full",
    moe=MoEConfig(n_experts=16, top_k=4),
    param_dtype="bfloat16", moment_dtype="bfloat16",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=96, vocab_size=256,
    norm="layernorm", activation="swiglu", rope_style="full",
    moe=MoEConfig(n_experts=4, top_k=2),
    compute_dtype="float32",
)
