"""rwkv6-3b [ssm] — 32L d=2560 (attention-free) ff=8960 V=65536.

RWKV-6 "Finch": data-dependent decay time-mix + channel-mix.
Sub-quadratic: long_500k runs (O(1) recurrent state).
The attention IP family is INAPPLICABLE (no QK^T) — see DESIGN.md
§Arch-applicability; projections still route through the matmul IPs.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    norm="layernorm", activation="relu_sq", rope_style="none",
    rwkv=RWKVConfig(head_size=64),
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="rwkv6-3b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=224, vocab_size=256,
    norm="layernorm", activation="relu_sq", rope_style="none",
    rwkv=RWKVConfig(head_size=16, lora_rank_decay=8, lora_rank_mix=8),
    compute_dtype="float32", sub_quadratic=True,
)
