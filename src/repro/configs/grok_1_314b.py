"""grok-1-314b [moe] — 64L d=6144 48H (GQA kv=8) ff=32768 V=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    norm="rmsnorm", activation="geglu", rope_style="full",
    moe=MoEConfig(n_experts=8, top_k=2),
    param_dtype="bfloat16", moment_dtype="bfloat16",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256,
    norm="rmsnorm", activation="geglu", rope_style="full",
    moe=MoEConfig(n_experts=4, top_k=2),
    compute_dtype="float32",
)
