"""seamless-m4t-large-v2 [audio] — enc-dec 24L d=1024 16H ff=8192 V=256206.

Transformer BACKBONE only: the speech frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, frames, d_model).
[arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206,
    norm="layernorm", activation="gelu", rope_style="none",
    pos_embed="sinusoidal", embed_inputs=True,
)

SMOKE = ModelConfig(
    name="seamless-m4t-large-v2-smoke", family="encdec",
    n_layers=2, enc_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    norm="layernorm", activation="gelu", rope_style="none",
    pos_embed="sinusoidal", embed_inputs=True, compute_dtype="float32",
)
