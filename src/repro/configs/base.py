"""Config system: one dataclass family covering all assigned architectures.

Every architecture file in this package exports:
  CONFIG       — the exact published configuration (full scale)
  SMOKE        — a reduced same-family configuration for CPU smoke tests
Registry access: ``repro.configs.get_config(name, smoke=False)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    moe_every: int = 1          # 1 = every FFN is MoE; 2 = alternate (jamba)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    lora_rank_decay: int = 64
    lora_rank_mix: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- variants ---
    norm: str = "rmsnorm"       # rmsnorm | layernorm | layernorm_nonparam
    activation: str = "swiglu"  # swiglu | gelu | relu_sq
    rope_style: str = "full"    # full | half (chatglm 2d) | none
    rope_theta: float = 10_000.0
    pos_embed: str = "none"     # none | sinusoidal (used when rope_style=none)
    tie_embeddings: bool = False
    # hybrid (jamba): one attention layer every `attn_every` layers; others mamba
    attn_every: int = 0         # 0 = all attention (or all-ssm if family=="ssm")
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # encdec
    enc_layers: int = 0         # >0 -> encoder-decoder; n_layers = decoder layers
    # modality frontend stub: inputs are precomputed embeddings, not token ids
    embed_inputs: bool = False
    # --- numerics / memory policy ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    logit_dtype: str = "bfloat16"   # dtype logits are materialized in
    # dtype attention score chunks are *materialized* in (softmax stats
    # stay f32); bfloat16 halves the dominant S^2 HBM term — §Perf knob
    attn_score_dtype: str = "float32"
    # skip fully-masked causal kv chunks (graph twin of the Pallas
    # kernel's pl.when block skip; exact) — §Perf knob
    causal_skip: bool = False
    # MoE dispatch: "einsum" (GShard dense one-hot contractions) or
    # "scatter" (indexed scatter/gather — no E*C one-hot traffic) — §Perf
    moe_dispatch: str = "einsum"
    remat: str = "block"        # none | block | block_dots (save matmul outs)
    scan_layers: bool = True
    # --- distribution policy ---
    fsdp: bool = False          # ZeRO-3-style param sharding over dp axes
    # --- technique: resource-driven IP selection policy (paper core) ---
    ip_budget: str = "default"  # default | mxu_scarce | vmem_tight | int8
    sub_quadratic: bool = False # True for ssm/hybrid: long_500k is runnable

    # ------------------------------------------------------------------
    @property
    def group_size(self) -> int:
        """GQA group."""
        return self.n_heads // self.n_kv_heads if self.n_kv_heads else 0

    @property
    def attn_layout(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn' | 'mamba' | 'rwkv'."""
        if self.family == "ssm":
            return tuple("rwkv" for _ in range(self.n_layers))
        if self.attn_every > 1:
            return tuple("attn" if i % self.attn_every == 0 else "mamba"
                         for i in range(self.n_layers))
        return tuple("attn" for _ in range(self.n_layers))

    @property
    def d_inner(self) -> int:
        mc = self.mamba or MambaConfig()
        return mc.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        mc = self.mamba or MambaConfig()
        return mc.dt_rank or -(-self.d_model // 16)

    def dtype(self, which: str):
        return jnp.dtype(getattr(self, which + "_dtype"))

    # ---- parameter count (for 6ND model FLOPs) --------------------------
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        Hq, Hkv, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        n = V * D                     # embed
        if not self.tie_embeddings:
            n += D * V                # lm head
        attn = D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D
        if self.activation in ("swiglu", "geglu"):
            dense_ffn = 3 * D * F
        else:
            dense_ffn = 2 * D * F
        mc = self.mamba or MambaConfig()
        d_in, d_st, dtr = self.d_inner, mc.d_state, self.dt_rank
        mamba = (D * 2 * d_in + mc.d_conv * d_in + d_in * (dtr + 2 * d_st)
                 + dtr * d_in + d_in * D + d_in * d_st + d_in)
        rc = self.rwkv or RWKVConfig()
        rwkv_tm = 4 * D * D + D * D + 2 * rc.lora_rank_decay * D
        rwkv_cm = int(2 * D * (F if F else 4 * D))
        for i, kind in enumerate(self.attn_layout):
            if kind == "attn":
                n += attn
            elif kind == "mamba":
                n += mamba
            else:
                n += rwkv_tm + rwkv_cm
            if kind == "rwkv":
                continue  # rwkv channel-mix already counted
            if self.moe and (i % self.moe.moe_every == 0):
                e = self.moe.top_k if active_only else self.moe.n_experts
                n += e * dense_ffn + D * self.moe.n_experts
            else:
                n += dense_ffn
        if self.enc_layers:
            enc_block = attn + dense_ffn
            cross = attn
            n += self.enc_layers * enc_block + self.n_layers * cross
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 524k context requires "
                       "sub-quadratic attention (skip per assignment)")
    return True, ""
