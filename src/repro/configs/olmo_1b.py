"""olmo-1b [dense] — 16L d=2048 16H (MHA kv=16) ff=8192 V=50304.

Non-parametric LayerNorm (the OLMo signature), SwiGLU, full RoPE.
[arXiv:2402.00838; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=50304,
    norm="layernorm_nonparam", activation="swiglu", rope_style="full",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo-1b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    norm="layernorm_nonparam", activation="swiglu", rope_style="full",
    tie_embeddings=True, compute_dtype="float32",
)
