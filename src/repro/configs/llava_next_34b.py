"""llava-next-34b [vlm] — 60L d=7168 56H (GQA kv=8) ff=20480 V=64000.

Backbone only (anyres patch tiling is the STUB frontend): input_specs()
provides precomputed patch embeddings mixed with text embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    norm="rmsnorm", activation="swiglu", rope_style="full",
    embed_inputs=True,
    param_dtype="bfloat16", moment_dtype="bfloat16",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=256,
    norm="rmsnorm", activation="swiglu", rope_style="full",
    embed_inputs=True, compute_dtype="float32",
)
