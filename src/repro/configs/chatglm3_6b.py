"""chatglm3-6b [dense] — 28L d=4096 32H (GQA kv=2) ff=13696 V=65024.

2D-RoPE (applied to half the head dims), GQA kv=2, RMSNorm + SwiGLU.
[arXiv:2406.12793; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=65024,
    norm="rmsnorm", activation="swiglu", rope_style="half",
)

SMOKE = ModelConfig(
    name="chatglm3-6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=256,
    norm="rmsnorm", activation="swiglu", rope_style="half",
    compute_dtype="float32",
)
