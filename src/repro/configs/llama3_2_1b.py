"""llama3.2-1b [dense] — 16L d=2048 32H (GQA kv=8) ff=8192 V=128256.

head_dim = 64 (32 heads x 64 = 2048); RMSNorm + SwiGLU + RoPE.
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256,
    norm="rmsnorm", activation="swiglu", rope_style="full",
    rope_theta=500_000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=512,
    norm="rmsnorm", activation="swiglu", rope_style="full",
    rope_theta=500_000.0, tie_embeddings=True, compute_dtype="float32",
)
