"""jamba-1.5-large-398b [hybrid] — 72L d=8192 64H (GQA kv=8) ff=24576
V=65536; Mamba+attention 1:7 interleave; MoE 16e top-2 on alternate layers.
Sub-quadratic: long_500k runs (attention layers are 1/8 of the stack;
their KV is sequence-sharded). [arXiv:2403.19887; hf]
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    norm="rmsnorm", activation="swiglu", rope_style="none",
    attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, moe_every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    param_dtype="bfloat16", moment_dtype="bfloat16",
    fsdp=True, sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256,
    norm="rmsnorm", activation="swiglu", rope_style="none",
    attn_every=2,
    moe=MoEConfig(n_experts=4, top_k=2, moe_every=2),
    mamba=MambaConfig(d_state=4, d_conv=2, expand=2),
    compute_dtype="float32", sub_quadratic=True,
)
