"""starcoder2-15b [dense] — 40L d=6144 48H (GQA kv=4) ff=24576 V=49152.

GQA + RoPE; LayerNorm + GeLU (starcoder2 uses standard LN/MLP).
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    norm="layernorm", activation="gelu", rope_style="full",
)

SMOKE = ModelConfig(
    name="starcoder2-15b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=256, vocab_size=256,
    norm="layernorm", activation="gelu", rope_style="full",
    compute_dtype="float32",
)
